#!/usr/bin/env python
"""Docstring gate for the public TM surface (wired into scripts/ci.sh).

Walks the listed modules with ``ast`` (stdlib only — no imports of the
checked code, no new dependencies) and requires a docstring on:

  * the module itself,
  * every public top-level function and class,
  * every public method of a public class.

"Public" means the name has no leading underscore (dunders like
``__init__`` are skipped too — their contract is the class docstring).
A method may inherit its docstring: if any base class *named in the
checked module set* defines the same method with a docstring, the
override passes (the registry engines document the contract once on
``EvalEngine``; per-engine overrides would only repeat it).

Exit status 1 lists every missing docstring as ``path:line name``.
"""
from __future__ import annotations

import ast
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parents[1]

# The public surface the README points users at (ISSUE 5 satellite):
MODULES = [
    "src/repro/core/types.py",
    "src/repro/core/tm.py",
    "src/repro/core/distributed.py",
    "src/repro/core/api.py",
    "src/repro/core/session.py",
    "src/repro/core/engines.py",
    "src/repro/kernels/backend.py",
    "src/repro/kernels/indexed.py",
    "src/repro/checkpoint/tm_store.py",
    "src/repro/serving/__init__.py",
    "src/repro/serving/aot.py",
    "src/repro/serving/fairness.py",
    "src/repro/serving/loadgen.py",
    "src/repro/serving/runtime.py",
]


def _documented_methods(cls: ast.ClassDef) -> dict[str, bool]:
    """{method name: has docstring} for one class body."""
    out = {}
    for node in cls.body:
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            out[node.name] = ast.get_docstring(node) is not None
    return out


def check(paths: list[str]) -> list[str]:
    """Missing-docstring records (``path:line name``) across ``paths``."""
    trees: dict[str, ast.Module] = {}
    # class name -> {method: has_doc}, across every checked module, so an
    # override can inherit its doc from a base defined in another module
    class_methods: dict[str, dict[str, bool]] = {}
    class_bases: dict[str, list[str]] = {}
    for rel in paths:
        tree = ast.parse((REPO / rel).read_text(), filename=rel)
        trees[rel] = tree
        for node in tree.body:
            if isinstance(node, ast.ClassDef):
                class_methods[node.name] = _documented_methods(node)
                class_bases[node.name] = [
                    b.id for b in node.bases if isinstance(b, ast.Name)]

    def inherited_doc(cls_name: str, method: str,
                      seen: frozenset = frozenset()) -> bool:
        for base in class_bases.get(cls_name, []):
            if base in seen:
                continue
            if class_methods.get(base, {}).get(method):
                return True
            if inherited_doc(base, method, seen | {cls_name}):
                return True
        return False

    missing = []
    for rel, tree in trees.items():
        if ast.get_docstring(tree) is None:
            missing.append(f"{rel}:1 <module>")
        for node in tree.body:
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                if node.name.startswith("_"):
                    continue
                if ast.get_docstring(node) is None:
                    missing.append(f"{rel}:{node.lineno} {node.name}()")
            elif isinstance(node, ast.ClassDef):
                if node.name.startswith("_"):
                    continue
                if ast.get_docstring(node) is None:
                    missing.append(f"{rel}:{node.lineno} class {node.name}")
                for meth in node.body:
                    if not isinstance(meth, (ast.FunctionDef,
                                             ast.AsyncFunctionDef)):
                        continue
                    if meth.name.startswith("_"):
                        continue
                    if ast.get_docstring(meth) is not None:
                        continue
                    if inherited_doc(node.name, meth.name):
                        continue
                    missing.append(
                        f"{rel}:{meth.lineno} {node.name}.{meth.name}()")
    return missing


def main() -> int:
    """Check ``MODULES`` (or argv paths); print misses; 0 iff none."""
    paths = sys.argv[1:] or MODULES
    missing = check(paths)
    if missing:
        print(f"{len(missing)} public definitions without docstrings:")
        for m in missing:
            print("  " + m)
        return 1
    print(f"docstring gate OK: {len(paths)} modules, every public "
          "class/function documented")
    return 0


if __name__ == "__main__":
    sys.exit(main())
