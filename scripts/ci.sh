#!/usr/bin/env bash
# CI smoke: tier-1 tests + the quickstart example on the estimator API +
# one scaled-down benchmark cell + the TM serving smoke. Run from anywhere:
#
#     bash scripts/ci.sh
#
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== hygiene: no compiled artifacts tracked =="
if git ls-files | grep -q '\.pyc$'; then
  echo "ERROR: *.pyc files are git-tracked:" >&2
  git ls-files | grep '\.pyc$' >&2
  exit 1
fi

echo "== tier-1 tests =="
# Deselected: pre-existing-at-seed mixtral prefill/decode mismatch (tracked
# as a ROADMAP.md open item). The sharding subprocess test is back in (the
# jax-compat shims in launch/mesh.py + sharding.py fixed it on jax 0.4.37),
# and the TM sharded-parity + session-topology-parity subprocess tests ride
# with it — the three `slow` tests put this gate at ~30 min on the 1-core
# container; use `pytest -m "not slow"` for a fast local loop (pytest.ini).
python -m pytest -x -q \
  --deselect "tests/test_models_smoke.py::test_prefill_decode_consistency[mixtral-8x7b]"

echo "== quickstart (TsetlinMachine estimator API) =="
python examples/quickstart.py

echo "== benchmark smoke cell =="
python -m benchmarks.run --smoke

echo "== tm_serve smoke (sharded TM serving on a forced 4-device mesh) =="
rm -f BENCH_tm_serve.json
XLA_FLAGS=--xla_force_host_platform_device_count=4 \
  python -m repro.launch.tm_serve --smoke
python - <<'EOF'
import json
d = json.load(open("BENCH_tm_serve.json"))
assert d["engines"], "no engine records in BENCH_tm_serve.json"
# the smoke must exercise the sharded scores path on the 4-device mesh and
# record the device count + per-device-count batch-axis scaling
assert d["devices"] == 4, f"device count not recorded: {d.get('devices')}"
assert d["topology"]["sharded"], d["topology"]
sweep = {row["devices"]: row for row in d["batch_axis_scaling"]}
assert set(sweep) == {1, 2, 4}, sweep
for n_dev, row in sweep.items():
    assert row["throughput_rps"] > 0, (n_dev, row)
for name, r in d["engines"].items():
    lat = r["latency_ms"]
    assert {"p50", "p90", "p95", "p99"} <= set(lat), (name, lat)
    assert r["throughput_rps"] > 0, (name, r)
print("BENCH_tm_serve.json well-formed:", ", ".join(d["engines"]),
      "| scaling devices:", sorted(sweep))
EOF

echo "CI smoke: OK"
