#!/usr/bin/env bash
# CI smoke: tier-1 tests + the quickstart example on the estimator API +
# one scaled-down benchmark cell. Run from anywhere:
#
#     bash scripts/ci.sh
#
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== tier-1 tests =="
# Deselected: failures that pre-date the engine-registry work (tracked as
# ROADMAP.md open items) — mixtral prefill/decode mismatch, and the sharding
# subprocess test which needs jax.sharding.AxisType (absent in the
# container's jax 0.4.37). Kept out so the smoke gate stays meaningful.
python -m pytest -x -q \
  --deselect "tests/test_models_smoke.py::test_prefill_decode_consistency[mixtral-8x7b]" \
  --deselect "tests/test_sharding.py::test_sharded_equivalence_subprocess"

echo "== quickstart (TsetlinMachine estimator API) =="
python examples/quickstart.py

echo "== benchmark smoke cell =="
python -m benchmarks.run --smoke

echo "CI smoke: OK"
