#!/usr/bin/env bash
# CI smoke: tier-1 tests + the quickstart example on the estimator API +
# one scaled-down benchmark cell + the TM serving smoke. Run from anywhere:
#
#     bash scripts/ci.sh
#
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== hygiene: no compiled artifacts tracked =="
if git ls-files | grep -q '\.pyc$'; then
  echo "ERROR: *.pyc files are git-tracked:" >&2
  git ls-files | grep '\.pyc$' >&2
  exit 1
fi

echo "== docstring gate (public TM surface, README satellite) =="
python scripts/check_docstrings.py

echo "== tier-1 tests =="
# The seed's mixtral prefill/decode deselect is gone: inference MoE routing
# is dropless now (models/moe.py), so prefill and step-wise decode agree.
# The `slow` subprocess tests (sharding, TM sharded/session/backends/ragged
# parity) put this gate at ~40 min on the 1-core container; use
# `pytest -m "not slow"` for a fast local loop (pytest.ini).
python -m pytest -x -q

echo "== README quickstart (executed from the doc, never drifts) =="
python - <<'EOF'
import pathlib, re
text = pathlib.Path("README.md").read_text()
m = re.search(r"<!-- ci-quickstart -->\s*```python\n(.*?)```", text, re.S)
assert m, "no <!-- ci-quickstart --> python block in README.md"
exec(compile(m.group(1), "README.md#quickstart", "exec"),
     {"__name__": "__main__"})
EOF

echo "== quickstart (TsetlinMachine estimator API) =="
python examples/quickstart.py

echo "== benchmark smoke cell =="
python -m benchmarks.run --smoke

echo "== tm_serve smoke (async serving runtime, sharded Pallas-interpret, 4-device mesh) =="
rm -f BENCH_tm_serve.json
XLA_FLAGS=--xla_force_host_platform_device_count=4 \
  python -m repro.launch.tm_serve --smoke --backend pallas_interpret
python - <<'EOF'
import json
d = json.load(open("BENCH_tm_serve.json"))
assert d["engines"], "no engine records in BENCH_tm_serve.json"
assert d["schema"] == 2, f"expected schema 2, got {d.get('schema')}"
# the smoke must exercise the sharded scores path on the 4-device mesh and
# record the device count + per-device-count batch-axis scaling, serving the
# packed engine through the Pallas-interpret kernel route
assert d["devices"] == 4, f"device count not recorded: {d.get('devices')}"
assert d["topology"]["sharded"], d["topology"]
assert d["topology"]["backend"] == "pallas_interpret", d["topology"]
# §9: the fired composition rule is part of the topology metadata
assert d["topology"]["composition"] in (
    "composed_even", "composed_ragged", "clause_only"), d["topology"]
# §11 satellite: the per-shard row census (where ragged padding lands)
rows = d["topology"]["shard_rows"]
assert len(rows) == d["topology"]["clause_shards"], rows
assert all({"shard", "real_rows", "pad_rows"} <= set(r) for r in rows), rows
assert "bitpack" in d["engines"], list(d["engines"])
sweep = {row["devices"]: row for row in d["batch_axis_scaling"]}
assert set(sweep) == {1, 2, 4}, sweep
for n_dev, row in sweep.items():
    assert row["throughput_rps"] > 0, (n_dev, row)
for name, r in d["engines"].items():
    lat = r["latency_ms"]
    assert {"p50", "p90", "p95", "p99"} <= set(lat), (name, lat)
    assert r["throughput_rps"] > 0, (name, r)
    # compile keys are strings by contract (docs/BENCH_SCHEMAS.md)
    assert all(isinstance(k, str) for k in r["compile_s_per_bucket"]), r
# §10: the open-loop sync-vs-async sustained_load section is well-formed —
# offered/achieved/rejections per step, a knee identified, and the AOT
# hot-loop invariant held (zero compilations, zero misses in the timed loop)
sl = d["sustained_load"]
assert set(sl["engines"]) == set(d["engines"]), sl.keys()
for name, r in sl["engines"].items():
    assert r["open_loop"] and r["steps"], (name, r)
    for s in r["steps"]:
        assert {"offered_rps", "achieved_rps", "rejection_rate",
                "latency_ms"} <= set(s), (name, s)
    assert r["knee"]["index"] in range(len(r["steps"])), (name, r["knee"])
    assert r["knee"]["criterion"], (name, r["knee"])
    assert r["sync_baseline"]["achieved_rps"] > 0, (name, r)
    assert r["aot"]["hot_loop_compiles"] == 0, (name, r["aot"])
    assert r["aot"]["misses"] == 0, (name, r["aot"])
    assert isinstance(r["knee_exceeds_sync"], bool), (name, r)
print("BENCH_tm_serve.json well-formed:", ", ".join(d["engines"]),
      "| scaling devices:", sorted(sweep),
      "| backend:", d["topology"]["backend"],
      "| sustained knees:", {n: r["knee"]["achieved_rps"]
                             for n, r in sl["engines"].items()})
EOF

echo "== dryrun --tm --async-votes (backend routes + vote all-reduce + async stale-vote path) =="
python -m repro.launch.dryrun --tm --async-votes
python - <<'EOF'
import json
# even cell (PR 3/4 contract) + the previously-indivisible ragged cell (§9)
for mesh, rule in (("2x4", "composed_even"), ("2x3", "composed_ragged")):
    d = json.load(open(f"results/dryrun/tm/{mesh}.json"))
    assert not d["failures"], d["failures"]
    routes = d["backend_routes"]
    # the Pallas route must actually run the kernel shard-locally, with the
    # (B, m) vote all-reduce still the only collective (DESIGN.md §8)
    pi = routes["pallas_interpret"]
    assert pi["pallas_call_in_jaxpr"] and pi["one_vote_all_reduce"], pi
    assert not routes["xla"]["pallas_call_in_jaxpr"], routes["xla"]
    # the indexed engine's matmul-form Eq. 4 routes the same way
    # (indexed_votes primitive: pallas_call ⇔ pallas backend, the one vote
    # all-reduce unchanged), and its train leg covers index_update — the
    # batched replay keeps the step all-reduce-only on both backends (§12)
    ipi = routes["indexed_pallas_interpret"]
    assert ipi["pallas_call_in_jaxpr"] and ipi["one_vote_all_reduce"], ipi
    assert ipi["train_step_all_reduce_only"], ipi
    ix = routes["indexed_xla"]
    assert not ix["pallas_call_in_jaxpr"], ix
    assert ix["one_vote_all_reduce"] and ix["train_step_all_reduce_only"], ix
    # the route record names which composition rule fired (§9)
    seq = d["train_step_sequential"]
    assert seq["composition"] == rule and seq["all_reduce_only"], seq
    print(f"dryrun --tm {mesh} OK: composition={seq['composition']},",
          {k: v["pallas_call_in_jaxpr"] for k, v in routes.items()})
# §11: the async route record — zero vote collectives inside the step
# (nothing at all on a clause-only mesh), exactly one batched all-reduce
# per K-step refresh, and the sync-minus-async collective arithmetic
a = json.load(open("results/dryrun/tm/async.json"))
assert not a["failures"], a["failures"]
assert set(a["cells"]) == {"1x4/sequential", "2x4/sequential",
                           "2x4/parallel"}, sorted(a["cells"])
for key, c in a["cells"].items():
    assert c["zero_vote_collectives"], (key, c)
    assert c["one_refresh_all_reduce"], (key, c)
    assert c["removed_vote_collectives"], (key, c)
assert a["cells"]["1x4/sequential"]["async_count"] == 0, a["cells"]
print("dryrun --tm async OK:",
      {k: f"sync={c['sync_count']} async={c['async_count']} "
          f"refresh={c['refresh_count']}" for k, c in a["cells"].items()})
EOF

echo "== BENCH_tm.json backend sweep (engine x backend x topology) =="
rm -f BENCH_tm.json
XLA_FLAGS=--xla_force_host_platform_device_count=4 \
  python -m benchmarks.tm_speedup --sweep-only
python - <<'EOF'
import json
d = json.load(open("BENCH_tm.json"))
assert d["schema"] == 4, f"expected schema 4, got {d.get('schema')}"
sweep = d["backend_sweep"]
assert sweep, "empty backend_sweep in BENCH_tm.json"
cells = {(r["engine"], r["backend"], r["clause_shards"], r["data_shards"])
         for r in sweep}
for engine in ("bitpack", "indexed"):
    for backend in ("xla", "pallas_interpret"):
        for shards in (1, 4):
            assert (engine, backend, shards, 1) in cells, (
                engine, backend, shards, sorted(cells))
        # §9: the ragged 2×2 data×clause cell rides along per backend
        assert (engine, backend, 2, 2) in cells, (
            engine, backend, sorted(cells))
ragged = [r for r in sweep if r["composition"] == "composed_ragged"]
assert ragged, [r["composition"] for r in sweep]
for r in sweep:
    assert r["infer_us"] > 0 and r["train_us"] > 0, r
    assert r["devices"] == 4, r
# §11: the sync-vs-async sweep — every K × shards cell present with a
# positive step time and its accuracy recorded next to the K=0 baseline;
# the removed vote collectives must show up as a step-time win for at
# least one K>0 cell on this forced-4-device host
sva = d["train_sync_vs_async"]
assert sva, "empty train_sync_vs_async in BENCH_tm.json"
cells = {(r["k"], r["clause_shards"]) for r in sva}
assert cells == {(k, s) for k in (0, 1, 4, 16) for s in (2, 4)}, cells
for r in sva:
    assert r["step_us"] > 0 and r["devices"] == 4, r
    assert 0.0 <= r["accuracy"] <= 1.0, r
    assert {"accuracy_sync", "accuracy_delta", "speedup_vs_sync",
            "composition"} <= set(r), r
best = max(r["speedup_vs_sync"] for r in sva if r["k"] > 0)
assert best > 1.0, f"async never beat sync: best speedup {best:.3f}"
# §12: the indexed-vs-dense speedup curve (schema 4) — work_ratio present
# on every cell, and at the paper-like sparse high-clause cell the
# matmul-form indexed engine must strictly beat dense on the full batch
curve = d["indexed_speedup"]
assert curve, "empty indexed_speedup in BENCH_tm.json"
for r in curve:
    assert r["work_ratio"] > 0, r
    assert r["infer_dense_us"] > 0 and r["infer_indexed_us"] > 0, r
sparse = min(curve, key=lambda r: (-r["n_clauses"], r["avg_clause_len"]))
assert sparse["infer_indexed_us"] < sparse["infer_dense_us"], sparse
print(f"BENCH_tm.json backend sweep well-formed: {len(sweep)} cells "
      f"({len(ragged)} composed_ragged); sync_vs_async {len(sva)} rows, "
      f"best async speedup {best:.2f}x; indexed_speedup {len(curve)} cells, "
      f"sparse high-clause cell {sparse['speedup']:.2f}x")
EOF

echo "CI smoke: OK"
