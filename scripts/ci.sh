#!/usr/bin/env bash
# CI smoke: tier-1 tests + the quickstart example on the estimator API +
# one scaled-down benchmark cell + the TM serving smoke. Run from anywhere:
#
#     bash scripts/ci.sh
#
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== hygiene: no compiled artifacts tracked =="
if git ls-files | grep -q '\.pyc$'; then
  echo "ERROR: *.pyc files are git-tracked:" >&2
  git ls-files | grep '\.pyc$' >&2
  exit 1
fi

echo "== tier-1 tests =="
# Deselected: pre-existing-at-seed mixtral prefill/decode mismatch (tracked
# as a ROADMAP.md open item). The sharding subprocess test is back in (the
# jax-compat shims in launch/mesh.py + sharding.py fixed it on jax 0.4.37),
# and the TM sharded-parity subprocess test rides with it — the two `slow`
# tests put this gate at ~20 min on the 1-core container; use
# `pytest -m "not slow"` for a fast local loop (pytest.ini).
python -m pytest -x -q \
  --deselect "tests/test_models_smoke.py::test_prefill_decode_consistency[mixtral-8x7b]"

echo "== quickstart (TsetlinMachine estimator API) =="
python examples/quickstart.py

echo "== benchmark smoke cell =="
python -m benchmarks.run --smoke

echo "== tm_serve smoke (batched TM serving) =="
rm -f BENCH_tm_serve.json
python -m repro.launch.tm_serve --smoke
python - <<'EOF'
import json
d = json.load(open("BENCH_tm_serve.json"))
assert d["engines"], "no engine records in BENCH_tm_serve.json"
for name, r in d["engines"].items():
    lat = r["latency_ms"]
    assert {"p50", "p90", "p95", "p99"} <= set(lat), (name, lat)
    assert r["throughput_rps"] > 0, (name, r)
print("BENCH_tm_serve.json well-formed:", ", ".join(d["engines"]))
EOF

echo "CI smoke: OK"
