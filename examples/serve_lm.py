"""Batched serving example: prefill + decode with rolling KV caches.

    PYTHONPATH=src python examples/serve_lm.py --arch mixtral-8x7b

Uses the reduced config of any assigned architecture (incl. MoE routing and
sliding-window rolling caches) and reports prefill/decode throughput.
"""
import argparse
import sys

sys.argv = sys.argv  # keep argparse happy under -m and direct invocation

from repro.launch.serve import main as serve_main  # noqa: E402

if __name__ == "__main__":
    serve_main()
