"""Quickstart: train a Tsetlin Machine with clause indexing in ~30 seconds.

    PYTHONPATH=src python examples/quickstart.py

Trains a small multiclass TM on synthetic binarized images, keeps the
paper's clause index in sync during learning, and shows that indexed
inference (falsification look-up, Eq. 4) gives identical predictions to
exhaustive evaluation.
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import TMConfig
from repro.core.driver import TMDriver
from repro.data.synthetic import binarized_images

cfg = TMConfig(n_classes=4, n_clauses=64, n_features=64, n_states=63,
               s=5.0, threshold=12)
driver = TMDriver.create(cfg)

x, y = binarized_images(1024, cfg.n_features, cfg.n_classes,
                        active=0.35, noise=0.03, seed=0)
x_tr, y_tr = jnp.asarray(x[:768]), jnp.asarray(y[:768])
x_te, y_te = jnp.asarray(x[768:]), jnp.asarray(y[768:])

key = jax.random.key(0)
for epoch in range(3):
    key, sub = jax.random.split(key)
    driver.train_batch(x_tr, y_tr, sub)          # dense learning + O(1)
    acc = driver.accuracy(x_te, y_te, engine="indexed")
    print(f"epoch {epoch}: test acc (indexed inference) = {acc:.3f}")

pred_dense = driver.predict(x_te, engine="dense")
pred_index = driver.predict(x_te, engine="indexed")
pred_kernel = driver.predict(x_te, engine="bitpack")
assert bool(jnp.all(pred_dense == pred_index)), "index != dense!"
assert bool(jnp.all(pred_dense == pred_kernel)), "kernel != dense!"
print("indexed == dense == pallas-kernel predictions ✓")

from repro.core.indexing import dense_work, indexed_work
w = float(np.asarray(indexed_work(driver.index, x_te)).mean())
print(f"work ratio (paper §3 Remarks): {w / dense_work(cfg):.4f} "
      f"(fraction of exhaustive literal inspections)")
