"""Quickstart: train a Tsetlin Machine with clause indexing in ~30 seconds.

    PYTHONPATH=src python examples/quickstart.py

Trains a small multiclass TM on synthetic binarized images through the
topology-aware ``TsetlinMachine`` estimator. Every registered evaluation
engine (exhaustive dense, Pallas bitpack, XLA bitpack, clause-compact
gather, and the paper's falsification index, Eq. 4) is kept in sync
event-wise during learning and gives identical predictions.

The ``topology=`` below is the default 1-device placement — swap in e.g.
``Topology(clause_shards=4)`` on a 4-device machine and the script runs
unchanged (and bit-exactly) through the sharded session path.
"""
import jax.numpy as jnp
import numpy as np

from repro.core import TMConfig, Topology, TsetlinMachine, registered_engines
from repro.data.synthetic import binarized_images

cfg = TMConfig(n_classes=4, n_clauses=64, n_features=64, n_states=63,
               s=5.0, threshold=12)
# Event buffer sized to the observed load (~4.2k crossings on the first
# full-batch step), not the 32k worst case: the buffer's overflow counter
# (asserted below after every epoch) turns an undersized buffer from silent
# cache staleness into a loud failure.
machine = TsetlinMachine(cfg, topology=Topology(), seed=0,
                         max_events_per_batch=8192).init()

x, y = binarized_images(1024, cfg.n_features, cfg.n_classes,
                        active=0.35, noise=0.03, seed=0)
x_tr, y_tr = jnp.asarray(x[:768]), jnp.asarray(y[:768])
x_te, y_te = jnp.asarray(x[768:]), jnp.asarray(y[768:])

for epoch in range(3):
    machine.partial_fit(x_tr, y_tr)              # jitted step; caches synced
    assert machine.event_overflow == 0, (
        f"event buffer overflowed ({machine.event_overflow} dropped): "
        "raise max_events_per_batch")
    acc = machine.evaluate(x_te, y_te, engine="indexed")
    print(f"epoch {epoch}: test acc (indexed inference) = {acc:.3f}")

preds = {name: machine.predict(x_te, engine=name)
         for name in registered_engines()}
for name, p in preds.items():
    assert bool(jnp.all(p == preds["dense"])), f"{name} != dense!"
print(f"all engines agree: {' == '.join(preds)} ✓")

from repro.core.indexing import dense_work, indexed_work
w = float(np.asarray(indexed_work(machine.index, x_te)).mean())
print(f"work ratio (paper §3 Remarks): {w / dense_work(cfg):.4f} "
      f"(fraction of exhaustive literal inspections)")
