"""End-to-end LM training example: a ~20M-param qwen3-family model.

    PYTHONPATH=src python examples/train_lm.py --steps 200

Exercises the full production path on CPU: sharded data pipeline →
microbatched train_step (bf16 compute, fp32 masters) → cosine schedule →
async checkpointing → loss goes down on a Zipf+ngram synthetic stream.
"""
import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.checkpoint.checkpointer import Checkpointer
from repro.configs import get_config
from repro.configs.base import ShapeSpec
from repro.data.pipeline import TokenBatcher
from repro.models.model import build
from repro.optim import adamw, compression
from repro.steps import make_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_lm_ckpt")
    args = ap.parse_args()

    # ~20M params: qwen3 geometry, 4 layers × d512
    cfg = dataclasses.replace(
        get_config("qwen3-1.7b"), n_layers=4, d_model=512, n_heads=8,
        n_kv_heads=4, head_dim=64, d_ff=1536, vocab=8192, remat=False,
        tie_embeddings=True)
    print(f"params ≈ {cfg.param_count()/1e6:.1f}M")

    shape = ShapeSpec("example", "train", args.seq, args.batch)
    step = make_step(cfg, shape, None, microbatches=2, peak_lr=1e-3,
                     warmup_steps=20, total_steps=args.steps)
    model = build(cfg)
    params = model.init(jax.random.key(0))
    state = {"params": params, "opt": adamw.init(params),
             "ef": compression.init_error_feedback(params)}
    step_fn = jax.jit(step.fn, donate_argnums=(0,))
    batcher = TokenBatcher(cfg.vocab, args.batch, args.seq, seed=3)
    ckpt = Checkpointer(args.ckpt_dir, keep=2)

    t0 = time.time()
    first = last = None
    for i in range(args.steps):
        batch = {k: jnp.asarray(v) for k, v in batcher(i).items()}
        state, metrics = step_fn(state, batch)
        if i == 0:
            first = float(metrics["nll"])
        if i % 20 == 0 or i == args.steps - 1:
            last = float(metrics["nll"])
            tps = args.batch * args.seq * (i + 1) / (time.time() - t0)
            print(f"step {i:4d}  nll {last:.4f}  lr {float(metrics['lr']):.2e}"
                  f"  {tps:.0f} tok/s")
        if (i + 1) % 100 == 0:
            ckpt.save(i + 1, state)
    ckpt.wait()
    print(f"\nnll {first:.3f} → {last:.3f} "
          f"({'improved ✓' if last < first else 'NOT improved ✗'})")


if __name__ == "__main__":
    main()
