"""End-to-end example (the paper's experiment): TM on MNIST-like data.

    PYTHONPATH=src python examples/tm_mnist.py [--epochs 5] [--clauses 512]
    XLA_FLAGS=--xla_force_host_platform_device_count=4 PYTHONPATH=src \
        python examples/tm_mnist.py --clause-shards 4

Full flow: synthetic binarized-MNIST stream → sequential (paper-faithful)
TM learning through the topology-aware estimator (pass ``--clause-shards``
/ ``--data-shards`` to run the identical script clause-sharded, bit-exact)
→ event-driven engine-cache maintenance → per-epoch accuracy → per-engine
throughput comparison + work-ratio report → versioned checkpoint
save/restore round-trip (schema v1: state + config fingerprint; caches
rebuild on the loading topology).
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import TMConfig, Topology, TsetlinMachine, registered_engines
from repro.core.indexing import dense_work, indexed_work
from repro.data.synthetic import binarized_images


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--epochs", type=int, default=4)
    ap.add_argument("--clauses", type=int, default=256)
    ap.add_argument("--features", type=int, default=784)
    ap.add_argument("--train", type=int, default=2048)
    ap.add_argument("--test", type=int, default=512)
    ap.add_argument("--clause-shards", type=int, default=1)
    ap.add_argument("--data-shards", type=int, default=1)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_tm_ckpt")
    ap.add_argument("--engines", default=None,
                    help="comma-separated engine names (default: registry)")
    ap.add_argument("--max-events", type=int, default=1 << 19,
                    help="cache-sync event buffer capacity per step "
                         "(overflow is asserted on, not silently dropped)")
    args = ap.parse_args()

    cfg = TMConfig(n_classes=10, n_clauses=args.clauses,
                   n_features=args.features, n_states=127, s=10.0,
                   threshold=25)
    x, y = binarized_images(args.train + args.test, cfg.n_features,
                            10, active=0.3, noise=0.02, seed=1)
    x_tr = jnp.asarray(x[:args.train]); y_tr = jnp.asarray(y[:args.train])
    x_te = jnp.asarray(x[args.train:]); y_te = jnp.asarray(y[args.train:])

    engines = tuple(args.engines.split(",")) if args.engines else None
    topology = Topology(clause_shards=args.clause_shards,
                        data_shards=args.data_shards, engines=engines)
    # Full-batch epochs cross many TA boundaries per step, but nowhere near
    # the n_classes·n_clauses·n_literals worst case (~4M here; the observed
    # load is ~150k). Size the buffer to the expected load and let the
    # overflow counter (asserted every epoch below) catch an undersized
    # buffer loudly instead of letting dropped events leave stale caches.
    machine = TsetlinMachine(cfg, topology=topology, seed=42,
                             max_events_per_batch=args.max_events).init()
    engines = machine.engines
    # sharded caches can't build on the fly: evaluate through a maintained one
    eval_engine = "indexed" if "indexed" in engines else engines[0]
    print("topology:", machine.session.describe())

    for epoch in range(args.epochs):
        t0 = time.time()
        machine.partial_fit(x_tr, y_tr)
        dt = time.time() - t0
        assert machine.event_overflow == 0, (
            f"event buffer overflowed ({machine.event_overflow} dropped "
            "events — caches are stale): raise --max-events")
        acc = machine.evaluate(x_te, y_te, engine=eval_engine)
        print(f"epoch {epoch}: acc={acc:.3f}  "
              f"train {args.train/dt:.0f} samples/s")
        machine.save(args.ckpt_dir, step=epoch, keep=2)

    # inference engine comparison (the paper's Table-4 style measurement),
    # driven through the registry — new engines show up automatically
    print("\ninference engines on", args.test, "samples:")
    for engine in engines:
        fn = lambda xx: machine.scores(xx, engine=engine)
        jax.block_until_ready(fn(x_te))  # compile
        t0 = time.time()
        jax.block_until_ready(fn(x_te))
        us = (time.time() - t0) / args.test * 1e6
        print(f"  {engine:12s}: {us:8.1f} us/sample")

    idx = machine.bundle.caches.get("indexed")
    if idx is None or machine.session.is_sharded:
        # --engines excluded 'indexed', or the maintained cache is a
        # shard-local layout (readable only through the sharded scores
        # path): build a global index once for the work-ratio report
        from repro.core import get_engine
        idx = get_engine("indexed").prepare(cfg, machine.state)
    w = float(np.asarray(indexed_work(idx, x_te)).mean())
    print(f"\nwork ratio: {w / dense_work(cfg):.4f} "
          "(paper reports ≈0.02 on trained MNIST TMs)")

    # versioned checkpoint round-trip — always restores single-device here,
    # regardless of the training topology (reshard-on-restore)
    restored = TsetlinMachine.load(args.ckpt_dir, cfg)
    same = bool(jnp.all(restored.predict(x_te, engine=eval_engine)
                        == machine.predict(x_te, engine=eval_engine)))
    print("checkpoint restore round-trip:", "ok" if same else "MISMATCH")


if __name__ == "__main__":
    main()
