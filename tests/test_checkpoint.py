"""Checkpointer: atomicity, retention, resharding restore, async safety."""
import json
import shutil
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.checkpointer import Checkpointer


def make_tree(seed=0):
    rng = np.random.default_rng(seed)
    return {
        "params": {"w": jnp.asarray(rng.normal(size=(8, 16)), jnp.float32),
                   "b": jnp.asarray(rng.normal(size=(16,)), jnp.float32)},
        "opt": {"mu": jnp.zeros((8, 16)), "step": jnp.asarray(7)},
    }


def test_save_restore_roundtrip(tmp_path):
    ck = Checkpointer(tmp_path)
    tree = make_tree()
    ck.save(100, tree, blocking=True)
    assert ck.latest_step() == 100
    out = ck.restore(100, jax.tree.map(jnp.zeros_like, tree))
    for a, b in zip(jax.tree.leaves(out), jax.tree.leaves(tree)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_async_save_then_wait(tmp_path):
    ck = Checkpointer(tmp_path)
    ck.save(5, make_tree())
    ck.wait()
    assert ck.latest_step() == 5


def test_atomicity_tmp_dirs_ignored(tmp_path):
    ck = Checkpointer(tmp_path)
    ck.save(10, make_tree(), blocking=True)
    # simulate a crash mid-write of a newer checkpoint
    (tmp_path / "step_00000020.tmp").mkdir()
    assert ck.latest_step() == 10
    # and a committed-but-manifestless dir is also ignored
    (tmp_path / "step_00000030").mkdir()
    assert ck.latest_step() == 10


def test_retention(tmp_path):
    ck = Checkpointer(tmp_path, keep=2, keep_every=100)
    for s in (100, 150, 200, 250):
        ck.save(s, make_tree(), blocking=True)
    steps = sorted(int(p.name.split("_")[1])
                   for p in tmp_path.glob("step_*"))
    assert 250 in steps and 200 in steps          # newest two
    assert 100 in steps                           # archival multiple
    assert 150 not in steps                       # GC'd


def test_restore_shape_mismatch_raises(tmp_path):
    ck = Checkpointer(tmp_path)
    ck.save(1, {"w": jnp.zeros((4, 4))}, blocking=True)
    with pytest.raises(ValueError):
        ck.restore(1, {"w": jnp.zeros((4, 5))})


def test_reshard_on_restore(tmp_path):
    """Restore onto an explicit sharding (elastic-mesh path)."""
    ck = Checkpointer(tmp_path)
    tree = {"w": jnp.arange(64, dtype=jnp.float32).reshape(8, 8)}
    ck.save(3, tree, blocking=True)
    mesh = jax.sharding.Mesh(np.array(jax.devices()[:1]).reshape(1, 1),
                             ("data", "model"))
    sh = {"w": jax.sharding.NamedSharding(
        mesh, jax.sharding.PartitionSpec("data", None))}
    out = ck.restore(3, {"w": jnp.zeros((8, 8))}, shardings=sh)
    assert out["w"].sharding == sh["w"]
    np.testing.assert_array_equal(np.asarray(out["w"]), np.asarray(tree["w"]))
