"""Train/prefill/decode step builders on CPU (no mesh): loss decreases,
metadata is lowering-complete, microbatching is loss-equivalent."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduce_config
from repro.configs.base import ShapeSpec
from repro.data.pipeline import TokenBatcher
from repro.models.model import build
from repro.optim import adamw, compression
from repro.steps import make_decode_step, make_prefill_step, make_train_step

SMALL = ShapeSpec("t", "train", 32, 8)


def tiny_cfg():
    return dataclasses.replace(
        get_config("qwen3-1.7b"), n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=2, head_dim=16, d_ff=128, vocab=256, remat=False)


def init_state(cfg):
    model = build(cfg)
    params = model.init(jax.random.key(0))
    return {"params": params, "opt": adamw.init(params),
            "ef": compression.init_error_feedback(params)}


def test_train_step_decreases_loss():
    cfg = tiny_cfg()
    step = make_train_step(cfg, SMALL, None, microbatches=2, peak_lr=2e-3,
                           warmup_steps=5, total_steps=100)
    state = init_state(cfg)
    fn = jax.jit(step.fn, donate_argnums=(0,))
    batcher = TokenBatcher(cfg.vocab, SMALL.global_batch, SMALL.seq_len,
                           seed=0)
    first = last = None
    for i in range(25):
        batch = {k: jnp.asarray(v) for k, v in batcher(i % 4).items()}
        state, m = fn(state, batch)
        if i == 0:
            first = float(m["nll"])
        last = float(m["nll"])
    assert np.isfinite(last)
    assert last < first - 0.1, f"nll {first} -> {last}"


def test_microbatch_equivalence():
    """Grad accumulation over M microbatches == single big batch (fp32)."""
    cfg = tiny_cfg()
    batcher = TokenBatcher(cfg.vocab, SMALL.global_batch, SMALL.seq_len,
                           seed=1)
    batch = {k: jnp.asarray(v) for k, v in batcher(0).items()}
    outs = []
    for m in (1, 4):
        step = make_train_step(cfg, SMALL, None, microbatches=m,
                               peak_lr=1e-3, warmup_steps=0,
                               total_steps=10)
        state = init_state(cfg)
        new_state, metrics = jax.jit(step.fn)(state, batch)
        outs.append((new_state, metrics))
    # nll identical to fp32 accumulation precision
    np.testing.assert_allclose(float(outs[0][1]["nll"]),
                               float(outs[1][1]["nll"]), rtol=1e-5)
    # Adam normalizes by sqrt(v)≈|g| at step 1, amplifying bf16 grad noise
    # into O(lr)-scale update differences — compare with loose atol.
    w1 = outs[0][0]["params"]["layers"]["b0_attn_mlp"]["attn"]["wq"]
    w4 = outs[1][0]["params"]["layers"]["b0_attn_mlp"]["attn"]["wq"]
    np.testing.assert_allclose(np.asarray(w1), np.asarray(w4),
                               rtol=0.5, atol=4e-3)


def test_loop_dims_metadata():
    cfg = tiny_cfg()
    step = make_train_step(cfg, SMALL, None, microbatches=4)
    assert step.loop_dims == {"microbatches": 4, "layers": 2}
    wcfg = reduce_config(get_config("whisper-medium"))
    wstep = make_train_step(wcfg, SMALL, None, microbatches=2)
    assert wstep.loop_dims["enc_layers"] == wcfg.n_enc_layers
    hcfg = reduce_config(get_config("recurrentgemma-9b"))
    hstep = make_train_step(hcfg, SMALL, None, microbatches=2)
    assert hstep.loop_dims["layers"] == hcfg.n_layers // 3


def test_prefill_then_decode_steps_run():
    cfg = tiny_cfg()
    pshape = ShapeSpec("p", "prefill", 16, 2)
    dshape = ShapeSpec("d", "decode", 16, 2)
    pstep = make_prefill_step(cfg, pshape, None)
    dstep = make_decode_step(cfg, dshape, None)
    model = build(cfg)
    params = jax.tree.map(
        lambda x: x.astype(jnp.bfloat16) if x.dtype == jnp.float32 else x,
        model.init(jax.random.key(0)))
    toks = jnp.asarray(
        np.random.default_rng(0).integers(0, cfg.vocab, (2, 16)), jnp.int32)
    logits, cache = jax.jit(pstep.fn)(params, {"tokens": toks})
    assert logits.shape == (2, cfg.vocab)
    lg2, cache = jax.jit(dstep.fn)(
        params, cache, jnp.zeros((2, 1), jnp.int32),
        jnp.full((2,), 16, jnp.int32))
    assert lg2.shape == (2, cfg.vocab)
    assert bool(jnp.isfinite(lg2).all())


def test_structs_lower_without_allocation():
    """arg_structs + in_specs are lowering-complete on CPU (no mesh)."""
    cfg = tiny_cfg()
    step = make_train_step(cfg, SMALL, None, microbatches=2)
    lowered = jax.jit(step.fn).lower(*step.arg_structs)
    assert lowered is not None
