"""Ragged data×clause sharding (DESIGN.md §9): any topology composes.

Fast tests pin the pure resolution table (``distributed.clause_geometry``)
and the ceil-based per-shard index capacity. The slow subprocess is the
acceptance property on a forced **4-device** host platform: a previously
indivisible topology (``data_shards=2 × clause_shards=2`` on ``n_clauses``
whose per-shard slice does not divide by the data ranks) trains via
hierarchical composition **bit-exactly** with ``Topology(1)``, in both
learning modes, under both the ``xla`` and ``pallas_interpret`` kernel
backends — and the session reports the ``composed_ragged`` rule, never the
replication fallback.
"""
import subprocess
import sys
import textwrap
from pathlib import Path

import pytest

from repro.core import indexing
from repro.core.distributed import (
    COMPOSED_EVEN, COMPOSED_RAGGED, REPLICATED, clause_geometry)

SRC = str(Path(__file__).resolve().parents[1] / "src")


# ---------------------------------------------------------------------------
# Resolution table (pure — no devices)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize(
    "n_clauses,c,d,n_local,n_padded,n_sub,rule",
    [
        # PR-3 even composition unchanged
        (16, 4, 2, 4, 16, 2, COMPOSED_EVEN),
        (256, 4, 2, 64, 256, 32, COMPOSED_EVEN),
        # ragged sub-slices: n_local does not divide by data_shards
        (128, 3, 2, 43, 129, 22, COMPOSED_RAGGED),   # ISSUE acceptance shape
        (130, 2, 2, 65, 130, 33, COMPOSED_RAGGED),
        (10, 2, 4, 5, 10, 2, COMPOSED_RAGGED),       # pure-padding rank
        (14, 2, 3, 7, 14, 3, COMPOSED_RAGGED),       # prime per-shard count
        # escape hatch: more data ranks than clause rows → replicate
        (6, 2, 4, 3, 6, 3, REPLICATED),
        (2, 1, 4, 2, 2, 2, REPLICATED),
        # no data axis → nothing to compose
        (6, 2, 1, 3, 6, 3, "clause_only"),
        (10, 3, 1, 4, 12, 4, "clause_only"),         # ragged clause axis
    ],
)
def test_clause_geometry_table(n_clauses, c, d, n_local, n_padded, n_sub,
                               rule):
    g = clause_geometry(n_clauses, c, d)
    assert (g.n_local, g.n_padded, g.n_sub, g.composition) == (
        n_local, n_padded, n_sub, rule)
    assert g.ragged_clauses == (n_padded != n_clauses)
    if g.composes:
        # every real clause row is owned by exactly one (data, shard) slot
        assert d * g.n_sub >= g.n_local
        assert (d - 1) * g.n_sub < g.n_sub_padded
    assert g.n_sub_padded >= g.n_local


def test_shard_capacity_is_ceil():
    assert indexing.shard_capacity(128, 4) == 32      # divisible: unchanged
    assert indexing.shard_capacity(128, 3) == 43      # ragged: ceil
    assert indexing.shard_capacity(10, 4) == 3
    # per-shard worst case (its clause count) is always covered
    for n, s in [(128, 3), (10, 4), (7, 2), (6, 5)]:
        assert indexing.shard_capacity(n, s) >= -(-n // s)


def test_partitioning_declares_clause_padding():
    """The kernel contract names how each primitive tolerates padding rows
    (the §9 conventions the sharded wiring realises)."""
    from repro.kernels import backend as kbackend

    pad = {name: kbackend.get_primitive(name).partitioning.clause_padding
           for name in kbackend.registered_primitives()}
    assert pad["clause_votes"] == "zero_polarity"
    assert pad["ta_update"] == "masked_active"
    assert pad["clause_outputs"] == "caller_sliced"


# ---------------------------------------------------------------------------
# Acceptance: forced-4-device subprocess, both backends, both modes
# ---------------------------------------------------------------------------

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import dataclasses
    import jax, jax.numpy as jnp
    import numpy as np

    from repro.core import (
        TMConfig, Topology, TsetlinMachine, registered_engines)

    # n_clauses=6 over clause_shards=2 -> n_local=3; data_shards=2 does not
    # divide it -> the PR-3 path silently replicated; now: composed_ragged
    # (rank 0 owns 2 rows, rank 1 owns 1 row + 1 padding row per shard)
    cfg = TMConfig(n_classes=3, n_clauses=6, n_features=10, n_states=50,
                   s=3.0, threshold=4)
    ALL = cfg.n_classes * cfg.n_clauses * cfg.n_literals
    ragged = Topology(data_shards=2, clause_shards=2)
    rng = np.random.default_rng(0)
    # 20 samples at batch_size=8 -> trailing partial batch pads under a mask
    xs = jnp.asarray(rng.integers(0, 2, (20, 10)), jnp.uint8)
    ys = jnp.asarray(rng.integers(0, 3, 20), jnp.int32)
    xe = jnp.asarray(rng.integers(0, 2, (8, 10)), jnp.uint8)

    for parallel in (False, True):
        ref = TsetlinMachine(cfg, topology=Topology(), parallel=parallel,
                             max_events_per_batch=ALL, seed=7).init()
        ref.fit(xs, ys, epochs=2, batch_size=8)
        ref_ta = np.asarray(ref.state.ta_state)
        ref_pred = np.asarray(ref.predict(xe, engine="dense"))
        for backend in ("xla", "pallas_interpret"):
            topo = dataclasses.replace(ragged, backend=backend)
            m = TsetlinMachine(cfg, topology=topo, parallel=parallel,
                               max_events_per_batch=ALL, seed=7).init()
            d = m.session.describe()
            want_rule = "batch_parallel" if parallel else "composed_ragged"
            assert d["composition"] == want_rule, d
            assert d["backend"] == backend, d
            m.fit(xs, ys, epochs=2, batch_size=8)
            tag = f"{backend} parallel={parallel}"
            np.testing.assert_array_equal(
                np.asarray(m.state.ta_state), ref_ta, err_msg=tag)
            assert m.event_overflow == 0, tag
            for engine in registered_engines():
                np.testing.assert_array_equal(
                    np.asarray(m.predict(xe, engine=engine)), ref_pred,
                    err_msg=f"{tag}/{engine}")
    print("tm-ragged-parity-ok")
""")


@pytest.mark.slow
def test_tm_ragged_composition_subprocess():
    res = subprocess.run(
        [sys.executable, "-c", SCRIPT],
        env={"PYTHONPATH": SRC, "PATH": "/usr/bin:/bin", "HOME": "/root"},
        capture_output=True, text=True, timeout=900)
    assert res.returncode == 0, res.stdout + "\n" + res.stderr
    assert "tm-ragged-parity-ok" in res.stdout, (
        res.stdout + "\n" + res.stderr)
