"""Clause index (paper §3): O(1) maintenance, inference equivalence."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:  # property test uses hypothesis when present; seeded fallback otherwise
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

from repro.core import (
    TMConfig, TMState, apply_events, build_index, compact,
    compact_apply_events, compact_eval, compact_scores, delete,
    dense_clause_outputs, empty_index, events_from_transition,
    index_update, indexed_scores, indexed_work, insert, init_tm, scores,
    validate,
)
from repro.core import ref
from repro.core.indexing import Event
from repro.core.types import include_mask

CFG = TMConfig(n_classes=3, n_clauses=8, n_features=6, n_states=50,
               s=3.0, threshold=4, empty_clause_output=1)
CAP = CFG.n_clauses  # worst-case capacity


def random_state(cfg, seed=0, density=0.4):
    rng = np.random.default_rng(seed)
    inc = rng.uniform(size=(cfg.n_classes, cfg.n_clauses, cfg.n_literals)) < density
    ta = np.where(inc, cfg.n_states + 1, cfg.n_states)
    return TMState(ta_state=jnp.asarray(ta, jnp.int16))


# ---------------------------------------------------------------------------
# Structure invariants
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("seed", range(4))
def test_build_index_invariants(seed):
    state = random_state(CFG, seed)
    idx = build_index(CFG, state, CAP)
    checks = validate(CFG, state, idx)
    for name, ok in checks.items():
        assert bool(ok), name


def test_empty_index_is_valid():
    state = init_tm(CFG)
    idx = empty_index(CFG, CAP)
    checks = validate(CFG, state, idx)
    for name, ok in checks.items():
        assert bool(ok), name


def test_insert_then_delete_roundtrip():
    """Paper's step-by-step example semantics: swap-with-last + pos fixup."""
    idx = empty_index(CFG, CAP)
    i, k = jnp.asarray(1), jnp.asarray(3)
    # insert clauses 2, 5, 7 into list (1, 3)
    for j in (2, 5, 7):
        idx = insert(idx, i, jnp.asarray(j), k)
    assert int(idx.counts[1, 3]) == 3
    np.testing.assert_array_equal(np.asarray(idx.lists[1, 3, :3]), [2, 5, 7])
    assert int(idx.pos[1, 5, 3]) == 1
    # delete the middle element: 7 swaps into its slot
    idx = delete(idx, i, jnp.asarray(5), k)
    assert int(idx.counts[1, 3]) == 2
    np.testing.assert_array_equal(np.asarray(idx.lists[1, 3, :2]), [2, 7])
    assert int(idx.pos[1, 7, 3]) == 1
    assert int(idx.pos[1, 5, 3]) == -1


def _check_event_replay_equals_rebuild(ops):
    """Property body: replaying any insert/delete sequence ≡ batch rebuild."""
    inc = np.zeros((CFG.n_classes, CFG.n_clauses, CFG.n_literals), bool)
    idx = empty_index(CFG, CAP)
    for (i, j, k) in ops:
        if inc[i, j, k]:
            idx = delete(idx, jnp.asarray(i), jnp.asarray(j), jnp.asarray(k))
            inc[i, j, k] = False
        else:
            idx = insert(idx, jnp.asarray(i), jnp.asarray(j), jnp.asarray(k))
            inc[i, j, k] = True
    ta = np.where(inc, CFG.n_states + 1, CFG.n_states)
    state = TMState(ta_state=jnp.asarray(ta, jnp.int16))
    checks = validate(CFG, state, idx)
    for name, ok in checks.items():
        assert bool(ok), name
    # counts must agree with a fresh build (list *order* may differ — the
    # index is a set structure; validate() checks the bijection)
    fresh = build_index(CFG, state, CAP)
    np.testing.assert_array_equal(np.asarray(idx.counts), np.asarray(fresh.counts))


if HAVE_HYPOTHESIS:
    @settings(max_examples=25, deadline=None)
    @given(st.lists(st.tuples(st.integers(0, CFG.n_classes - 1),
                              st.integers(0, CFG.n_clauses - 1),
                              st.integers(0, CFG.n_literals - 1)),
                    min_size=1, max_size=40))
    def test_event_replay_equals_rebuild(ops):
        _check_event_replay_equals_rebuild(ops)
else:
    @pytest.mark.parametrize("seed", range(6))
    def test_event_replay_equals_rebuild(seed):
        rng = np.random.default_rng(seed)
        n_ops = int(rng.integers(1, 41))
        ops = [(int(rng.integers(0, CFG.n_classes)),
                int(rng.integers(0, CFG.n_clauses)),
                int(rng.integers(0, CFG.n_literals)))
               for _ in range(n_ops)]
        _check_event_replay_equals_rebuild(ops)


def test_apply_events_masked_buffer():
    state0 = init_tm(CFG)
    state1 = random_state(CFG, 5)
    old_inc = include_mask(CFG, state0)
    new_inc = include_mask(CFG, state1)
    n_changed = int(np.asarray(old_inc != new_inc).sum())
    buf = events_from_transition(old_inc, new_inc, max_events=n_changed + 8)
    assert int(buf.overflow) == 0
    idx = apply_events(empty_index(CFG, CAP), buf.events)
    checks = validate(CFG, state1, idx)
    for name, ok in checks.items():
        assert bool(ok), name


# ---------------------------------------------------------------------------
# Inference equivalence (the paper's core claim: same predictions, less work)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("seed", range(4))
def test_indexed_scores_equal_dense_scores(seed):
    state = random_state(CFG, seed)
    idx = build_index(CFG, state, CAP)
    rng = np.random.default_rng(300 + seed)
    xs = jnp.asarray(rng.integers(0, 2, (7, CFG.n_features)), jnp.uint8)
    got = indexed_scores(CFG, idx, xs)
    want = scores(CFG, state, xs)  # empty_clause_output=1 (paper Eq. 4 mode)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("seed", range(3))
def test_indexed_scores_match_numpy_list_oracle(seed):
    state = random_state(CFG, seed)
    idx = build_index(CFG, state, CAP)
    rng = np.random.default_rng(400 + seed)
    xs = rng.integers(0, 2, (5, CFG.n_features)).astype(np.uint8)
    got = np.asarray(indexed_scores(CFG, idx, jnp.asarray(xs)))
    for b in range(xs.shape[0]):
        want = ref.indexed_scores_ref(np.asarray(idx.lists),
                                      np.asarray(idx.counts),
                                      xs[b], CFG.n_clauses)
        np.testing.assert_array_equal(got[b], want)


@pytest.mark.parametrize("seed", range(3))
def test_compact_eval_equals_dense(seed):
    state = random_state(CFG, seed)
    lmax = int(np.asarray(include_mask(CFG, state).sum(-1)).max())
    comp = compact(CFG, state, lmax)
    rng = np.random.default_rng(500 + seed)
    xs = jnp.asarray(rng.integers(0, 2, (6, CFG.n_features)), jnp.uint8)
    got = compact_eval(CFG, comp, xs)
    want = dense_clause_outputs(CFG, state, xs, empty_output=1)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    np.testing.assert_array_equal(
        np.asarray(compact_scores(CFG, comp, xs)),
        np.asarray(scores(CFG, state, xs)))


@pytest.mark.parametrize("seed", range(3))
def test_compact_apply_events_equals_rebuild(seed):
    """Event replay on the clause-compact layout ≡ fresh compact() build.

    Rows are sets (compact_eval is order-blind), so equality is on lengths
    and per-row membership, not slot order."""
    state0 = random_state(CFG, seed)
    state1 = random_state(CFG, 100 + seed)
    old_inc = include_mask(CFG, state0)
    new_inc = include_mask(CFG, state1)
    l_max = CFG.n_literals  # worst-case capacity
    comp = compact(CFG, state0, l_max)
    n_changed = int(np.asarray(old_inc != new_inc).sum())
    buf = events_from_transition(old_inc, new_inc, n_changed + 4)
    got = compact_apply_events(comp, buf.events)
    want = compact(CFG, state1, l_max)
    np.testing.assert_array_equal(np.asarray(got.lengths),
                                  np.asarray(want.lengths))
    got_rows = np.sort(np.asarray(got.lit_idx), axis=-1)
    want_rows = np.sort(np.asarray(want.lit_idx), axis=-1)
    np.testing.assert_array_equal(got_rows, want_rows)


def test_compact_apply_events_overflow_is_contained():
    """Capacity overflow loses literals (config error) but never corrupts
    surviving entries: inserts past ℓ_max clamp, deletes of never-absorbed
    literals are no-ops, and validate_compact flags the loss."""
    from repro.core import validate_compact
    from repro.core.indexing import Event
    l_max = 2
    state0 = TMState(ta_state=jnp.full(
        (CFG.n_classes, CFG.n_clauses, CFG.n_literals), CFG.n_states,
        jnp.int16))
    comp = compact(CFG, state0, l_max)
    # insert 3 literals into clause (0, 0): third overflows
    ev = Event(cls=jnp.zeros(3, jnp.int32),
               clause=jnp.zeros(3, jnp.int32),
               literal=jnp.arange(3, dtype=jnp.int32),
               is_insert=jnp.ones(3, bool), valid=jnp.ones(3, bool))
    comp = compact_apply_events(comp, ev)
    assert int(comp.lengths[0, 0]) == l_max  # clamped, not 3
    # deleting the dropped literal 2 must not disturb survivors {0, 1}
    ev_del = Event(cls=jnp.zeros(1, jnp.int32), clause=jnp.zeros(1, jnp.int32),
                   literal=jnp.full(1, 2, jnp.int32),
                   is_insert=jnp.zeros(1, bool), valid=jnp.ones(1, bool))
    comp = compact_apply_events(comp, ev_del)
    np.testing.assert_array_equal(
        np.sort(np.asarray(comp.lit_idx[0, 0])), [0, 1])
    # validate_compact surfaces the loss vs the true include mask
    ta = np.full((CFG.n_classes, CFG.n_clauses, CFG.n_literals),
                 CFG.n_states, np.int16)
    ta[0, 0, :3] = CFG.n_states + 1  # literals 0,1,2 included, 2 after delete
    ta[0, 0, 2] = CFG.n_states      # literal 2 deleted again
    checks = validate_compact(
        CFG, TMState(ta_state=jnp.asarray(ta)), comp)
    assert bool(checks["overflow_ok"]) and bool(checks["member_ok"])


def test_validate_compact_on_fresh_build():
    from repro.core import validate_compact
    state = random_state(CFG, 3)
    comp = compact(CFG, state, CFG.n_literals)
    for name, ok in validate_compact(CFG, state, comp).items():
        assert bool(ok), name


def test_indexed_work_metric():
    """Work == Σ_{k false} counts[i,k] — the quantity in §3 'Remarks'."""
    state = random_state(CFG, 9, density=0.2)
    idx = build_index(CFG, state, CAP)
    x = np.zeros(CFG.n_features, np.uint8)  # all features 0 → x-literals false
    w = int(indexed_work(idx, jnp.asarray(x[None]))[0])
    counts = np.asarray(idx.counts)
    want = counts[:, :CFG.n_features].sum()  # false literals = first o
    assert w == want


# ---------------------------------------------------------------------------
# Batched replay (index_update) ≡ sequential oracle ≡ fresh build
# ---------------------------------------------------------------------------


def _assert_index_set_equal(got, want):
    """Set-level index equality: counts and membership bit-exact, each list's
    live prefix equal as a *set* (intra-list slot order is the one thing
    sequential swap-with-last and batched compaction may disagree on, and
    nothing observes it), NA padding beyond counts."""
    cnts = np.asarray(want.counts)
    np.testing.assert_array_equal(np.asarray(got.counts), cnts)
    np.testing.assert_array_equal(np.asarray(got.pos) != -1,
                                  np.asarray(want.pos) != -1)
    gl, wl = np.asarray(got.lists), np.asarray(want.lists)
    m, L, cap = gl.shape
    for i in range(m):
        for k in range(L):
            c = cnts[i, k]
            assert sorted(gl[i, k, :c]) == sorted(wl[i, k, :c]), (i, k)
            assert (gl[i, k, c:] == -1).all(), (i, k)


@pytest.mark.parametrize("seed", range(4))
def test_index_update_equals_sequential_and_rebuild(seed):
    """Real transition buffers (masked tails included): batched replay ≡
    scan-of-cond replay ≡ fresh build, and the result validates."""
    state0 = random_state(CFG, seed)
    state1 = random_state(CFG, 50 + seed)
    old_inc = include_mask(CFG, state0)
    new_inc = include_mask(CFG, state1)
    n_changed = int(np.asarray(old_inc != new_inc).sum())
    buf = events_from_transition(old_inc, new_inc, max_events=n_changed + 7)
    idx0 = build_index(CFG, state0, CAP)
    seq = apply_events(idx0, buf.events)
    bat = index_update(idx0, buf.events)
    _assert_index_set_equal(bat, seq)
    _assert_index_set_equal(bat, build_index(CFG, state1, CAP))
    for name, ok in validate(CFG, state1, bat).items():
        assert bool(ok), name


@pytest.mark.parametrize("seed", range(4))
def test_index_update_same_cell_and_same_list_multiples(seed):
    """Adversarial buffers: repeated events on the same (i, j, k) cell
    (strictly alternating — the apply_events precondition), many events on
    the same list, plus a garbage invalid tail that must be ignored."""
    rng = np.random.default_rng(seed)
    state0 = random_state(CFG, seed)
    cur = np.asarray(include_mask(CFG, state0)).copy()
    idx0 = build_index(CFG, state0, CAP)
    # concentrate on two literals so lists absorb many events each, and
    # revisit cells freely: each revisit flips direction (delete-then-insert
    # and insert-then-delete of the same cell both occur)
    ks = rng.choice(CFG.n_literals, size=2, replace=False)
    rows = []
    for _ in range(28):
        i = int(rng.integers(CFG.n_classes))
        j = int(rng.integers(CFG.n_clauses))
        k = int(ks[rng.integers(2)])
        rows.append((i, j, k, not cur[i, j, k], True))
        cur[i, j, k] = not cur[i, j, k]
    for _ in range(4):  # invalid tail: arbitrary fields, must be no-ops
        rows.append((int(rng.integers(CFG.n_classes)),
                     int(rng.integers(CFG.n_clauses)),
                     int(rng.integers(CFG.n_literals)),
                     bool(rng.integers(2)), False))
    ev = Event(
        cls=jnp.asarray([r[0] for r in rows], jnp.int32),
        clause=jnp.asarray([r[1] for r in rows], jnp.int32),
        literal=jnp.asarray([r[2] for r in rows], jnp.int32),
        is_insert=jnp.asarray([r[3] for r in rows]),
        valid=jnp.asarray([r[4] for r in rows]))
    seq = apply_events(idx0, ev)
    bat = index_update(idx0, ev)
    _assert_index_set_equal(bat, seq)
    ta = np.where(cur, CFG.n_states + 1, CFG.n_states)
    state1 = TMState(ta_state=jnp.asarray(ta, jnp.int16))
    _assert_index_set_equal(bat, build_index(CFG, state1, CAP))
    for name, ok in validate(CFG, state1, bat).items():
        assert bool(ok), name


def test_index_update_overflow_counts_match_sequential():
    """Capacity overflow: counts keep the exact sequential value (±1 per
    valid event — the config error stays observable via validate), and the
    in-capacity prefix matches the sequential survivors."""
    cap = 2
    idx0 = empty_index(CFG, cap)
    ev = Event(cls=jnp.zeros(4, jnp.int32),
               clause=jnp.arange(4, dtype=jnp.int32),
               literal=jnp.full(4, 3, jnp.int32),
               is_insert=jnp.ones(4, bool), valid=jnp.ones(4, bool))
    seq = apply_events(idx0, ev)
    bat = index_update(idx0, ev)
    np.testing.assert_array_equal(np.asarray(bat.counts),
                                  np.asarray(seq.counts))
    assert int(bat.counts[0, 3]) == 4 > cap  # overflow accounted, not hidden
    np.testing.assert_array_equal(np.asarray(bat.pos) != -1,
                                  np.asarray(seq.pos) != -1)
    np.testing.assert_array_equal(np.asarray(bat.lists[0, 3]),
                                  np.asarray(seq.lists[0, 3]))  # [0, 1]


# ---------------------------------------------------------------------------
# events_from_transition: cumsum selection ≡ the old stable argsort
# ---------------------------------------------------------------------------


def _events_argsort_reference(old_inc, new_inc, max_events):
    """The pre-optimisation selection, verbatim: stable argsort of the
    changed mask, first max_events cells (regression oracle)."""
    flat = (np.asarray(old_inc) != np.asarray(new_inc)).reshape(-1)
    order = np.argsort(~flat, kind="stable")
    sel = order[:max_events]
    m, n, L = np.asarray(old_inc).shape
    cls, rem = np.divmod(sel, n * L)
    clause, literal = np.divmod(rem, L)
    overflow = max(int(flat.sum()) - max_events, 0)
    return (cls, clause, literal, np.asarray(new_inc).reshape(-1)[sel],
            flat[sel], overflow)


@pytest.mark.parametrize("seed,max_events", [
    (0, 64),        # room to spare: changed cells + unchanged fill
    (1, 16),        # tight
    (2, 5),         # overflow: more changed cells than buffer slots
    (3, 10_000),    # buffer larger than the cell count (degenerates to all)
])
def test_events_from_transition_matches_argsort_reference(seed, max_events):
    state0 = random_state(CFG, seed)
    state1 = random_state(CFG, 70 + seed)
    old_inc = include_mask(CFG, state0)
    new_inc = include_mask(CFG, state1)
    buf = events_from_transition(old_inc, new_inc, max_events)
    cls, clause, literal, is_insert, valid, overflow = \
        _events_argsort_reference(old_inc, new_inc, max_events)
    np.testing.assert_array_equal(np.asarray(buf.events.cls), cls)
    np.testing.assert_array_equal(np.asarray(buf.events.clause), clause)
    np.testing.assert_array_equal(np.asarray(buf.events.literal), literal)
    np.testing.assert_array_equal(np.asarray(buf.events.is_insert), is_insert)
    np.testing.assert_array_equal(np.asarray(buf.events.valid), valid)
    assert int(buf.overflow) == overflow


def test_index_sync_through_learning():
    """Dense learning + event-driven index maintenance stay in sync."""
    from repro.core import update_batch_sequential
    cfg = TMConfig(n_classes=2, n_clauses=6, n_features=5, n_states=20,
                   s=3.0, threshold=3)
    state = init_tm(cfg)
    idx = empty_index(cfg, cfg.n_clauses)
    key = jax.random.key(0)
    rng = np.random.default_rng(0)
    for step in range(5):
        key, sub = jax.random.split(key)
        xs = jnp.asarray(rng.integers(0, 2, (8, cfg.n_features)), jnp.uint8)
        ys = jnp.asarray(rng.integers(0, 2, 8), jnp.int32)
        old_inc = include_mask(cfg, state)
        state = update_batch_sequential(cfg, state, xs, ys, sub)
        new_inc = include_mask(cfg, state)
        buf = events_from_transition(old_inc, new_inc,
                                     max_events=int(cfg.n_classes * cfg.n_clauses * cfg.n_literals))
        assert int(buf.overflow) == 0
        idx = apply_events(idx, buf.events)
        checks = validate(cfg, state, idx)
        for name, ok in checks.items():
            assert bool(ok), f"step {step}: {name}"
