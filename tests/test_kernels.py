"""Pallas kernels (interpret mode) vs pure-jnp oracles: shape/dtype sweeps."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.bitpack import pack_bits, packed_literals, unpack_bits
from repro.core.types import TMConfig, TMState, include_mask
from repro.kernels import clause_eval
from repro.kernels import ref as kref
from repro.kernels import ta_update as ta_mod
from repro.kernels.ops import tm_clause_outputs, tm_predict, tm_votes


def make_case(m, n, o, b, seed, density=0.3):
    rng = np.random.default_rng(seed)
    include = rng.uniform(size=(m, n, 2 * o)) < density
    x = rng.integers(0, 2, (b, o)).astype(np.uint8)
    return jnp.asarray(include), jnp.asarray(x)


# ---------------------------------------------------------------------------
# bitpack round-trips
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("k", [1, 31, 32, 33, 100, 784, 1568])
def test_pack_unpack_roundtrip(k):
    rng = np.random.default_rng(k)
    bits = jnp.asarray(rng.integers(0, 2, (3, k)), jnp.uint8)
    words = pack_bits(bits)
    assert words.dtype == jnp.uint32
    np.testing.assert_array_equal(np.asarray(unpack_bits(words, k)),
                                  np.asarray(bits))


# ---------------------------------------------------------------------------
# fused votes kernel — sweep shapes incl. unaligned everything
# ---------------------------------------------------------------------------

SHAPES = [
    # (m, n, o, b) — deliberately unaligned to tiles
    (2, 4, 5, 3),
    (3, 8, 17, 9),
    (10, 130, 50, 8),     # clause dim > CLAUSE_TILE
    (2, 256, 784 // 4, 4),
    (1, 2, 2049, 2),      # literal words > LANE after packing? (2·2049/32=129)
]


@pytest.mark.parametrize("shape", SHAPES)
def test_clause_votes_packed_matches_ref(shape):
    m, n, o, b = shape
    include, x = make_case(m, n, o, b, seed=hash(shape) % 2**31)
    lit = jnp.concatenate([x, 1 - x], axis=-1)
    want = kref.clause_votes_ref(include, lit)
    pol = jnp.where(jnp.arange(n) < n // 2, 1, -1).astype(jnp.int32)
    got = clause_eval.clause_votes_packed(
        pack_bits(include.astype(jnp.uint8)), packed_literals(x), pol)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("shape", SHAPES[:3])
def test_clause_outputs_packed_matches_ref(shape):
    m, n, o, b = shape
    include, x = make_case(m, n, o, b, seed=hash(shape) % 2**31 + 1)
    lit = jnp.concatenate([x, 1 - x], axis=-1)
    want = kref.clause_outputs_ref(include, lit)
    got = clause_eval.clause_outputs_packed(
        pack_bits(include.astype(jnp.uint8)), packed_literals(x))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_kernel_agrees_with_tm_dense_path():
    """End-to-end: kernel votes == core dense scores (paper Eq. 3)."""
    from repro.core import scores
    cfg = TMConfig(n_classes=4, n_clauses=32, n_features=19, n_states=40)
    rng = np.random.default_rng(0)
    ta = rng.integers(1, 2 * cfg.n_states + 1,
                      (cfg.n_classes, cfg.n_clauses, cfg.n_literals))
    state = TMState(ta_state=jnp.asarray(ta, jnp.int16))
    x = jnp.asarray(rng.integers(0, 2, (6, cfg.n_features)), jnp.uint8)
    got = tm_votes(cfg, state, x)
    want = scores(cfg, state, x)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    np.testing.assert_array_equal(
        np.asarray(tm_predict(cfg, state, x)),
        np.asarray(jnp.argmax(want, -1)))


# ---------------------------------------------------------------------------
# TA-update kernel
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n,o", [(4, 5), (8, 17), (130, 70), (16, 200)])
@pytest.mark.parametrize("seed", [0, 1])
def test_ta_update_matches_ref(n, o, seed):
    rng = np.random.default_rng(seed)
    L = 2 * o
    n_states = 50
    ta = jnp.asarray(rng.integers(1, 2 * n_states + 1, (n, L)), jnp.int16)
    lit = jnp.asarray(rng.integers(0, 2, L), jnp.int8)
    cout = jnp.asarray(rng.integers(0, 2, n), jnp.int8)
    t1 = jnp.asarray(rng.integers(0, 2, n), bool)
    act = jnp.asarray(rng.integers(0, 2, n), bool)
    u = jnp.asarray(rng.uniform(size=(n, L)), jnp.float32)
    got = ta_mod.ta_update(ta, lit, cout, t1, act, u,
                           n_states=n_states, s=3.7)
    want = kref.ta_update_ref(ta, lit, cout, t1, act, u,
                              n_states=n_states, s=3.7)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_ta_update_bounds():
    """States pinned at the boundaries stay in [1, 2N]."""
    n, L, n_states = 8, 256, 10
    ta = jnp.concatenate([
        jnp.full((n, L // 2), 1, jnp.int16),
        jnp.full((n, L // 2), 2 * n_states, jnp.int16)], axis=1)
    lit = jnp.zeros(L, jnp.int8)
    cout = jnp.ones(n, jnp.int8)
    t1 = jnp.ones(n, bool)
    act = jnp.ones(n, bool)
    u = jnp.zeros((n, L), jnp.float32)  # all transitions fire
    out = np.asarray(ta_mod.ta_update(ta, lit, cout, t1, act, u,
                                      n_states=n_states, s=2.0))
    assert out.min() >= 1 and out.max() <= 2 * n_states
