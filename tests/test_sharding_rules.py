"""Guard: every large parameter must be sharded by the partition rules.

A rule gap replicates the leaf onto all 256/512 devices; on qwen2-moe and
recurrentgemma that silently cost 13–35 GiB/device (found via the dry-run
memory analysis — EXPERIMENTS.md §Perf iteration 0e). This test fails on
any future arch/param addition whose big tensors miss the rules.
"""
import jax
import numpy as np
import pytest

from repro.configs import ARCHS, get_config
from repro.models.model import build
from repro.sharding import param_specs

BIG = 1_000_000  # elements


@pytest.mark.parametrize("arch", ARCHS)
def test_big_params_are_sharded(arch):
    cfg = get_config(arch)
    model = build(cfg)
    if cfg.family == "encdec":
        struct = jax.eval_shape(lambda: model.init(jax.random.key(0), 4096))
    else:
        struct = jax.eval_shape(lambda: model.init(jax.random.key(0)))
    specs = param_specs(struct, stacked_prefixes=("layers", "enc_layers"))

    flat_s = jax.tree_util.tree_flatten_with_path(struct)[0]
    flat_p = jax.tree_util.tree_flatten(specs)[0]
    offenders = []
    for (kp, leaf), spec in zip(flat_s, flat_p):
        per_layer = int(np.prod(leaf.shape))
        path = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                        for k in kp)
        stacked = path.startswith(("layers", "enc_layers"))
        if stacked:
            per_layer //= leaf.shape[0]
        if per_layer >= BIG and all(s is None for s in spec):
            offenders.append((path, leaf.shape, spec))
    assert not offenders, (
        "replicated big params (add partition rules in sharding.py):\n"
        + "\n".join(f"  {p} {s} {sp}" for p, s, sp in offenders))
