"""Property-based kernel tests (hypothesis): random shapes/densities/inputs."""
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.bitpack import pack_bits, packed_literals
from repro.kernels import clause_eval
from repro.kernels import ref as kref


@settings(max_examples=20, deadline=None)
@given(
    m=st.integers(1, 4),
    n_half=st.integers(1, 40),
    o=st.integers(1, 120),
    b=st.integers(1, 10),
    density=st.floats(0.0, 1.0),
    seed=st.integers(0, 2**31 - 1),
)
def test_votes_kernel_any_shape(m, n_half, o, b, density, seed):
    n = 2 * n_half
    rng = np.random.default_rng(seed)
    include = jnp.asarray(rng.uniform(size=(m, n, 2 * o)) < density)
    x = jnp.asarray(rng.integers(0, 2, (b, o)), jnp.uint8)
    lit = jnp.concatenate([x, 1 - x], axis=-1)
    want = kref.clause_votes_ref(include, lit)
    pol = jnp.where(jnp.arange(n) < n_half, 1, -1).astype(jnp.int32)
    got = clause_eval.clause_votes_packed(
        pack_bits(include.astype(jnp.uint8)), packed_literals(x), pol)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@settings(max_examples=15, deadline=None)
@given(
    n_half=st.integers(1, 30),
    o=st.integers(1, 100),
    seed=st.integers(0, 2**31 - 1),
)
def test_votes_bounded_by_half_clauses(n_half, o, seed):
    """|votes| ≤ n/2 — structural invariant of Eq. 2/3."""
    n = 2 * n_half
    rng = np.random.default_rng(seed)
    include = jnp.asarray(rng.uniform(size=(1, n, 2 * o)) < 0.3)
    x = jnp.asarray(rng.integers(0, 2, (4, o)), jnp.uint8)
    pol = jnp.where(jnp.arange(n) < n_half, 1, -1).astype(jnp.int32)
    got = np.asarray(clause_eval.clause_votes_packed(
        pack_bits(include.astype(jnp.uint8)), packed_literals(x), pol))
    assert np.abs(got).max() <= n_half
