"""Serving runtime tests (src/repro/serving/, DESIGN.md §10).

Three layers, cheapest first:

  * deterministic units — ``Backlog`` admission arithmetic, typed
    ``Overloaded`` rejection, weighted round-robin tenant fairness, and
    latency accounting run against a stub AOT cache and an injectable
    fake clock: no threads, no jax dispatch, every assertion exact;
  * integration on a real (tiny) session — the AOT bucket cache compiles
    exactly one executable per (engine × bucket) and never again
    (``AOTCacheMiss`` instead of a silent retrace), and both server modes
    produce bit-exact ``session.scores`` results through their threaded
    paths;
  * a ``slow`` subprocess on a forced 4-device host mesh — the async
    server over ``Topology(data_shards=4)`` stays bit-exact against the
    sync scores path while its batching regroups rows into different
    padded buckets than the reference eval.
"""
import subprocess
import sys
import textwrap
from pathlib import Path

import numpy as np
import pytest

SRC = str(Path(__file__).resolve().parents[1] / "src")


# -- deterministic test doubles ---------------------------------------------


class FakeClock:
    """Injectable monotonic clock: time moves only when the test says so."""

    def __init__(self):
        self.t = 0.0

    def __call__(self) -> float:
        return self.t

    def advance(self, dt: float) -> None:
        self.t += dt


class StubAOT:
    """Duck-typed AOTBucketCache: records calls, computes nothing."""

    def __init__(self, sizes=(1, 2, 4, 8), n_features=6, n_classes=3):
        self.bucket_sizes = list(sizes)
        self.n_features = n_features
        self.n_classes = n_classes
        self.lowerings = len(sizes)
        self.hits = 0
        self.misses = 0
        self.calls = []

    def __call__(self, x, *, engine, bucket):
        assert x.shape == (bucket, self.n_features)
        self.hits += 1
        self.calls.append((engine, bucket))
        return np.zeros((bucket, self.n_classes), np.int32)

    def counters(self):
        return {"engines": 1, "buckets": len(self.bucket_sizes),
                "entries": len(self.bucket_sizes),
                "lowerings": self.lowerings, "hits": self.hits,
                "misses": self.misses}


def make_server(**kw):
    from repro.serving import AsyncTMServer

    stub = kw.pop("aot", None) or StubAOT()
    clock = kw.pop("clock", None) or FakeClock()
    server = AsyncTMServer(None, None, engine="stub", aot=stub,
                           clock=clock, **kw)
    return server, stub, clock


# -- backlog + admission ----------------------------------------------------


def test_backlog_bounds_rows_and_bytes():
    from repro.serving import Backlog

    b = Backlog(max_rows=3, max_bytes=20)
    assert b.try_admit(1, 6) and b.try_admit(1, 6) and b.try_admit(1, 6)
    assert not b.try_admit(1, 1)          # row budget exhausted
    b.release(1, 6)
    assert b.try_admit(1, 2)              # freed row readmits
    assert not b.try_admit(1, 7)          # 14 + 7 > 20: byte budget
    assert (b.rows, b.bytes) == (3, 14)
    with pytest.raises(ValueError):
        Backlog(max_rows=0, max_bytes=1)
    with pytest.raises(ValueError):
        Backlog(max_rows=1, max_bytes=0)


def test_overloaded_typed_rejection_and_release():
    from repro.serving import Overloaded, ScoreResult

    server, stub, clock = make_server(backlog_rows=4)
    clock.advance(1.0)
    admitted = [server.submit(np.zeros(6, np.uint8), tenant="acme")
                for _ in range(4)]
    assert not any(p.done for p in admitted)

    rej = server.submit(np.zeros(6, np.uint8), tenant="acme")
    assert rej.done                        # resolved inside submit
    over = rej.wait(0)
    assert isinstance(over, Overloaded)
    assert over.tenant == "acme" and over.arrival_s == 1.0
    assert over.backlog_rows == 4 and over.max_rows == 4

    clock.advance(2.5)
    assert server.step() == 4              # one synchronous round
    results = [p.wait(0) for p in admitted]
    assert all(isinstance(r, ScoreResult) for r in results)
    assert all(r.latency_s == 2.5 for r in results)
    assert server.backlog.rows == 0        # budget released on completion
    assert not server.submit(np.zeros(6, np.uint8)).done  # admits again

    stats = server.stats()
    assert stats["tenants"]["acme"]["admitted"] == 4
    assert stats["tenants"]["acme"]["rejected"] == 1
    assert stats["tenants"]["acme"]["latency_ms"]["p50"] == 2500.0


def test_byte_budget_rejects_before_row_budget():
    server, _, _ = make_server(backlog_rows=100, backlog_bytes=20)
    assert not server.submit(np.zeros(6, np.uint8)).done  # 6 bytes
    assert not server.submit(np.zeros(6, np.uint8)).done  # 12
    assert not server.submit(np.zeros(6, np.uint8)).done  # 18
    assert server.submit(np.zeros(6, np.uint8)).done      # 24 > 20: rejected


def test_dispatch_pads_to_bucket():
    server, stub, _ = make_server()
    for _ in range(3):
        server.submit(np.ones(6, np.uint8))
    assert server.step() == 3
    assert stub.calls == [("stub", 4)]     # 3 rows pad to the 4-bucket


# -- tenant fairness --------------------------------------------------------


def test_wrr_hot_tenant_cannot_starve_cold_ones():
    from repro.serving import TenantQueues

    q = TenantQueues()
    for i in range(100):
        q.push("hot", ("hot", i))
    for t in ("a", "b"):
        for i in range(3):
            q.push(t, (t, i))
    batch = q.take(9)
    # equal weights: each pass grants one row per tenant, so the flood is
    # held to its fair share and both cold tenants fully drain
    assert sum(1 for t, _ in batch if t == "hot") == 3
    assert sum(1 for t, _ in batch if t == "a") == 3
    assert sum(1 for t, _ in batch if t == "b") == 3
    # FIFO preserved within a tenant
    assert [i for t, i in batch if t == "hot"] == [0, 1, 2]
    assert len(q) == 97


def test_wrr_weights_shape_the_batch():
    from repro.serving import TenantQueues

    q = TenantQueues(weights={"big": 3})
    for i in range(10):
        q.push("big", ("big", i))
        q.push("small", ("small", i))
    batch = q.take(8)
    # per pass: big contributes 3, small 1 → 8 rows = two passes
    assert sum(1 for t, _ in batch if t == "big") == 6
    assert sum(1 for t, _ in batch if t == "small") == 2
    with pytest.raises(ValueError):
        TenantQueues(weights={"x": 0})


def test_wrr_start_rotates_between_takes():
    from repro.serving import TenantQueues

    q = TenantQueues()
    for i in range(4):
        q.push("a", ("a", i))
        q.push("b", ("b", i))
    first = q.take(1)[0][0]
    second = q.take(1)[0][0]
    assert {first, second} == {"a", "b"}   # no tenant owns the front


# -- loadgen records --------------------------------------------------------


def test_holds_and_find_knee():
    from repro.serving import find_knee, holds

    mk = lambda off, ach, rej: {"offered_rps": off, "achieved_rps": ach,
                                "rejection_rate": rej}
    assert holds(mk(100, 99, 0.0))
    assert not holds(mk(100, 70, 0.0))     # fell behind
    assert not holds(mk(100, 99, 0.02))    # rejecting
    steps = [mk(100, 99, 0.0), mk(200, 197, 0.0), mk(400, 250, 0.2)]
    knee = find_knee(steps)
    assert knee["index"] == 1 and knee["offered_rps"] == 200
    # nothing holds → fall back to the max-achieved step, and say so
    knee = find_knee([mk(100, 60, 0.5), mk(200, 90, 0.6)])
    assert knee["index"] == 1 and "max achieved" in knee["criterion"]


def test_poisson_arrivals_deterministic():
    from repro.serving import poisson_arrivals

    a = poisson_arrivals(100.0, 1.0, np.random.default_rng(7))
    b = poisson_arrivals(100.0, 1.0, np.random.default_rng(7))
    np.testing.assert_array_equal(a, b)
    assert a.size >= 1 and np.all(np.diff(a) >= 0) and a[-1] <= 1.0


# -- CLI flag resolution ----------------------------------------------------


def test_smoke_flags_are_defaults_not_overrides():
    from repro.launch.tm_serve import resolve_flags

    r = resolve_flags(True, requests=None, max_batch=None, classes=None)
    assert r == {"requests": 96, "max_batch": 8, "classes": 4}
    # explicitly-passed flags win over the smoke defaults
    r = resolve_flags(True, requests=32, max_batch=None, classes=12)
    assert r == {"requests": 32, "max_batch": 8, "classes": 12}
    r = resolve_flags(False, requests=None, engine=None)
    assert r == {"requests": 512, "engine": "indexed"}
    with pytest.raises(ValueError):
        resolve_flags(True, not_a_flag=1)


# -- real-session integration ----------------------------------------------


def _tiny_session(engines=("indexed",), topology=None):
    import jax.numpy as jnp
    from repro.core import TMConfig, TMState
    from repro.core.session import TMSession

    cfg = TMConfig(n_classes=3, n_clauses=16, n_features=12)
    rng = np.random.default_rng(0)
    inc = rng.uniform(size=(3, 16, 24)) < 0.25
    state = TMState(ta_state=jnp.asarray(
        np.where(inc, cfg.n_states + 1, cfg.n_states), jnp.int16))
    session = TMSession(cfg, topology, engines=engines)
    return session, session.prepare(state), rng


def test_aot_cache_compiles_each_bucket_exactly_once():
    import jax.numpy as jnp
    from repro.serving import AOTBucketCache, AOTCacheMiss

    session, bundle, rng = _tiny_session()
    cache = AOTBucketCache(session, bundle, engines=("indexed",),
                           max_batch=4)
    assert cache.bucket_sizes == [1, 2, 4]
    assert cache.counters()["lowerings"] == 3

    x = rng.integers(0, 2, (4, 12)).astype(np.uint8)
    ref = np.asarray(session.scores(bundle, jnp.asarray(x),
                                    engine="indexed"))
    for _ in range(2):                     # repeat calls never re-lower
        got = np.asarray(cache(x, engine="indexed", bucket=4))
    np.testing.assert_array_equal(got, ref)
    c = cache.counters()
    assert c["lowerings"] == 3 and c["hits"] == 2 and c["misses"] == 0

    with pytest.raises(AOTCacheMiss):
        cache(np.zeros((3, 12), np.uint8), engine="indexed", bucket=3)
    with pytest.raises(AOTCacheMiss):
        cache(x, engine="bitpack", bucket=4)
    assert cache.counters()["misses"] == 2
    assert cache.counters()["lowerings"] == 3   # misses never compile

    report = cache.compile_report()
    assert set(report) == {"indexed"}
    assert set(report["indexed"]) == {"1", "2", "4"}  # string keys (JSON)


@pytest.mark.parametrize("mode", ["async", "sync"])
def test_server_scores_bit_exact_through_threads(mode):
    import jax.numpy as jnp
    from repro.serving import AsyncTMServer, ScoreResult, SyncTMServer

    session, bundle, rng = _tiny_session()
    cls = AsyncTMServer if mode == "async" else SyncTMServer
    server = cls(session, bundle, engine="indexed", max_batch=4).start()
    xs = rng.integers(0, 2, (30, 12)).astype(np.uint8)
    try:
        promises = [server.submit(x, tenant=f"t{i % 2}")
                    for i, x in enumerate(xs)]
        server.drain(timeout=60)
        results = [p.wait(10) for p in promises]
    finally:
        server.stop()
    assert all(isinstance(r, ScoreResult) for r in results)
    ref = np.asarray(session.scores(bundle, jnp.asarray(xs),
                                    engine="indexed"))
    np.testing.assert_array_equal(np.stack([r.scores for r in results]), ref)

    stats = server.stats()
    assert stats["completed"] == 30 and stats["backlog_rows"] == 0
    assert stats["rows_real"] == 30
    assert stats["aot"]["misses"] == 0
    assert set(stats["tenants"]) == {"t0", "t1"}
    assert (stats["tenants"]["t0"]["served"]
            + stats["tenants"]["t1"]["served"]) == 30


def test_run_step_and_sustained_load_record_shape():
    from repro.serving import AsyncTMServer, run_step, sustained_load

    session, bundle, rng = _tiny_session()
    server = AsyncTMServer(session, bundle, engine="indexed",
                           max_batch=4).start()
    xs = rng.integers(0, 2, (64, 12)).astype(np.uint8)
    try:
        step = run_step(server, xs, rps=300.0, duration_s=0.1,
                        rng=np.random.default_rng(3))
        assert {"offered_rps", "achieved_rps", "requests", "completed",
                "rejected", "rejection_rate", "batches", "mean_batch",
                "padding_efficiency", "latency_ms"} <= set(step)
        assert step["completed"] + step["rejected"] == step["requests"]
        assert {"p50", "p95", "p99", "mean"} == set(step["latency_ms"])

        rec = sustained_load(server, xs, rps_steps=[200.0, 400.0],
                             step_duration_s=0.1, seed=1)
    finally:
        server.stop()
    assert rec["open_loop"] and rec["engine"] == "indexed"
    assert len(rec["steps"]) == 2
    assert rec["knee"]["index"] in (0, 1)
    assert rec["aot"]["hot_loop_compiles"] == 0
    assert rec["aot"]["misses"] == 0


def test_serve_engine_compile_keys_are_strings():
    from repro.core import TMConfig
    from repro.launch.tm_serve import ServePolicy, run

    record = run(TMConfig(n_classes=3, n_clauses=16, n_features=12),
                 engines=("indexed",), n_requests=12, rps=4000.0,
                 policy=ServePolicy(max_batch=4))
    keys = record["engines"]["indexed"]["compile_s_per_bucket"]
    assert set(keys) == {"1", "2", "4"}    # JSON-stable string keys


# -- forced-4-device parity (slow) ------------------------------------------

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.core import TMConfig, TMState
    from repro.core.session import TMSession, Topology
    from repro.serving import AsyncTMServer, SyncTMServer

    cfg = TMConfig(n_classes=5, n_clauses=32, n_features=24)
    rng = np.random.default_rng(0)
    inc = rng.uniform(size=(5, 32, 48)) < 0.2
    state = TMState(ta_state=jnp.asarray(
        np.where(inc, cfg.n_states + 1, cfg.n_states), jnp.int16))
    session = TMSession(cfg, Topology(data_shards=4),
                        engines=("indexed", "bitpack"))
    assert session.describe()["sharded"], session.describe()
    bundle = session.prepare(state)
    xs = rng.integers(0, 2, (64, 24)).astype(np.uint8)

    for engine in ("indexed", "bitpack"):
        ref = np.asarray(session.scores(bundle, jnp.asarray(xs),
                                        engine=engine))
        # async continuous batching regroups the 64 rows into padded
        # buckets of <= 8 over the 4-way data axis — results must still be
        # bit-exact against the one-shot sync eval
        server = AsyncTMServer(session, bundle, engine=engine,
                               max_batch=8).start()
        promises = [server.submit(x) for x in xs]
        server.drain(timeout=120)
        out = np.stack([p.wait(30).scores for p in promises])
        server.stop()
        c = server.aot.counters()
        assert c["misses"] == 0, c
        assert c["lowerings"] == c["entries"], c
        assert np.array_equal(out, ref), f"async mismatch: {engine}"
        print("serve-async-sharded-bitexact-ok", engine)

    server = SyncTMServer(session, bundle, engine="indexed",
                          max_batch=8).start()
    promises = [server.submit(x) for x in xs]
    server.drain(timeout=120)
    out = np.stack([p.wait(30).scores for p in promises])
    server.stop()
    ref = np.asarray(session.scores(bundle, jnp.asarray(xs),
                                    engine="indexed"))
    assert np.array_equal(out, ref), "sync mismatch"
    print("serve-sync-sharded-bitexact-ok")
""")


@pytest.mark.slow
def test_async_server_sharded_parity_subprocess():
    res = subprocess.run(
        [sys.executable, "-c", SCRIPT],
        env={"PYTHONPATH": SRC, "PATH": "/usr/bin:/bin", "HOME": "/root"},
        capture_output=True, text=True, timeout=900)
    assert res.returncode == 0, res.stdout + "\n" + res.stderr
    for marker in ("serve-async-sharded-bitexact-ok indexed",
                   "serve-async-sharded-bitexact-ok bitpack",
                   "serve-sync-sharded-bitexact-ok"):
        assert marker in res.stdout, res.stdout + "\n" + res.stderr
