"""MoE: engine equivalence, capacity drops, shared experts, aux loss."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.moe import init_moe, moe_block
from repro.sharding import Policy

POLICY = Policy.none()


def setup(e=4, d=16, f=8, n_shared=0, seed=0):
    p = init_moe(jax.random.key(seed), d, f, e, n_shared=n_shared)
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(2, 6, d)) * 0.3, jnp.float32)
    return p, x


@pytest.mark.parametrize("n_shared", [0, 2])
@pytest.mark.parametrize("cf", [0.5, 1.0, 2.0])
def test_sort_equals_einsum(n_shared, cf):
    """The two dispatch engines agree bit-for-bit-ish, incl. drops."""
    p, x = setup(n_shared=n_shared)
    outs = {}
    for eng in ("einsum", "sort"):
        out, aux = jax.jit(
            lambda p, x, eng=eng: moe_block(
                p, x, top_k=2, capacity_factor=cf, policy=POLICY,
                dispatch=eng))(p, x)
        outs[eng] = (np.asarray(out), float(aux))
    np.testing.assert_allclose(outs["sort"][0], outs["einsum"][0],
                               rtol=1e-5, atol=1e-6)
    assert outs["sort"][1] == pytest.approx(outs["einsum"][1])


def test_full_capacity_routes_everything():
    """cf high enough → output == explicit dense top-k mixture."""
    p, x = setup()
    out, _ = moe_block(p, x, top_k=2, capacity_factor=8.0, policy=POLICY,
                       dispatch="sort")
    # explicit reference: route each token through its top-2 experts
    logits = x.astype(jnp.float32) @ p["router"]
    probs = jax.nn.softmax(logits, -1)
    gates, experts = jax.lax.top_k(probs, 2)
    gates = gates / gates.sum(-1, keepdims=True)

    def one_tok(xt, gs, es):
        out = jnp.zeros_like(xt)
        for j in range(2):
            w1 = p["w_gate"][es[j]]
            w2 = p["w_up"][es[j]]
            w3 = p["w_down"][es[j]]
            h = jax.nn.silu(xt @ w1) * (xt @ w2)
            out = out + gs[j] * (h @ w3)
        return out

    want = jax.vmap(jax.vmap(one_tok))(x, gates, experts)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-4, atol=2e-5)


def test_capacity_drops_are_per_group():
    """cf tiny → per-expert slots exhaust within each group independently."""
    p, x = setup()
    out, _ = moe_block(p, x, top_k=2, capacity_factor=0.01, policy=POLICY,
                       dispatch="sort")
    # capacity = 1 slot/expert/group: not all tokens served, output finite
    assert bool(jnp.isfinite(out).all())
    assert float(jnp.abs(out).sum()) > 0


def test_aux_loss_prefers_balance():
    p, x = setup(e=2)
    x = jnp.abs(x) + 0.5          # positive features → deterministic winner
    # force router collapse to expert 0 → aux should exceed balanced value 1
    p = dict(p)
    p["router"] = jnp.zeros_like(p["router"]).at[:, 0].set(5.0)
    _, aux = moe_block(p, x, top_k=1, capacity_factor=2.0, policy=POLICY)
    assert float(aux) > 1.5  # E[aux]=1 at perfect balance (e·Σ 1/e·1/e)


def test_shared_expert_contributes():
    p, x = setup(n_shared=2)
    out_with, _ = moe_block(p, x, top_k=2, capacity_factor=2.0,
                            policy=POLICY)
    p2 = dict(p)
    p2.pop("shared")
    p2.pop("shared_gate")
    out_without, _ = moe_block(p2, x, top_k=2, capacity_factor=2.0,
                               policy=POLICY)
    assert float(jnp.abs(out_with - out_without).max()) > 1e-4
