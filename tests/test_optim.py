"""AdamW, schedules, gradient compression (error feedback)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.optim import adamw, compression
from repro.optim.schedule import cosine_with_warmup


def test_adamw_converges_on_quadratic():
    params = {"w": jnp.asarray([5.0, -3.0])}
    state = adamw.init(params)
    target = jnp.asarray([1.0, 2.0])

    @jax.jit
    def step(params, state):
        grads = jax.grad(
            lambda p: jnp.sum((p["w"] - target) ** 2))(params)
        return adamw.update(grads, state, params, lr=0.05, weight_decay=0.0)

    for _ in range(400):
        params, state, metrics = step(params, state)
    np.testing.assert_allclose(np.asarray(params["w"]), np.asarray(target),
                               atol=1e-2)
    assert int(state.step) == 400


def test_grad_clipping_bounds_update():
    g = {"w": jnp.asarray([1e6, -1e6])}
    clipped, norm = adamw.clip_by_global_norm(g, 1.0)
    assert float(adamw.global_norm(clipped)) <= 1.0 + 1e-5
    assert float(norm) > 1e5


def test_schedule_shape():
    s = jnp.asarray
    peak = 3e-4
    lr0 = cosine_with_warmup(s(0), peak_lr=peak, warmup_steps=100,
                             total_steps=1000)
    lr_peak = cosine_with_warmup(s(100), peak_lr=peak, warmup_steps=100,
                                 total_steps=1000)
    lr_end = cosine_with_warmup(s(1000), peak_lr=peak, warmup_steps=100,
                                total_steps=1000)
    assert float(lr0) == 0.0
    np.testing.assert_allclose(float(lr_peak), peak, rtol=1e-5)
    np.testing.assert_allclose(float(lr_end), 0.1 * peak, rtol=1e-3)


def test_compression_error_feedback_preserves_sum():
    """Σ_t compressed_t == Σ_t grads_t ± last residual (error feedback)."""
    rng = np.random.default_rng(0)
    params = {"w": jnp.zeros((64,))}
    ef = compression.init_error_feedback(params)
    total_true = np.zeros(64)
    total_comp = np.zeros(64)
    for t in range(30):
        g = {"w": jnp.asarray(rng.normal(size=64) * 1e-3, jnp.float32)}
        comp, ef = compression.compress_grads(g, ef, mode="int8")
        total_true += np.asarray(g["w"], np.float64)
        total_comp += np.asarray(comp["w"], np.float64)
    resid = np.asarray(ef.residual["w"])
    np.testing.assert_allclose(total_comp + resid, total_true, atol=1e-6)


def test_compression_bf16_dtype():
    params = {"w": jnp.zeros((8,))}
    ef = compression.init_error_feedback(params)
    g = {"w": jnp.asarray(np.linspace(-1, 1, 8), jnp.float32)}
    comp, ef = compression.compress_grads(g, ef, mode="bf16")
    assert comp["w"].dtype == jnp.bfloat16
    comp2, _ = compression.compress_grads(g, ef, mode="none")
    assert comp2["w"].dtype == jnp.float32
