"""Sharding tests on a multi-device host mesh (subprocess: 8 CPU devices).

Runs the real lowering path (param specs, activation constraints, the
flash-decode shard_map, the TM clause-sharded eval) on a 2×4 mesh and
checks numerical equivalence vs the unsharded path.
"""
import subprocess
import sys
import textwrap
from pathlib import Path

import pytest

SRC = str(Path(__file__).resolve().parents[1] / "src")

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import dataclasses
    import jax, jax.numpy as jnp
    import numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.configs import get_config
    from repro.configs.base import ShapeSpec
    from repro.launch.mesh import make_host_mesh, mesh_context
    from repro.models.model import build
    from repro.sharding import Policy, named_shardings, param_specs
    from repro.steps import make_decode_step, make_train_step

    mesh = make_host_mesh(data=2, model=4)

    # ---- decode: sharded flash-decode == unsharded dense decode ----
    cfg = dataclasses.replace(
        get_config("qwen3-1.7b"), n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=2, head_dim=16, d_ff=128, vocab=256, remat=False)
    model = build(cfg)
    params = jax.tree.map(
        lambda x: x.astype(jnp.bfloat16) if x.dtype == jnp.float32 else x,
        model.init(jax.random.key(0)))
    rng = np.random.default_rng(0)
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (2, 8)), jnp.int32)

    pol_none = Policy.none()
    cache = model.init_cache(2, 16)
    logits_ref = None
    c = cache
    for i in range(8):
        logits_ref, c = model.decode_step(
            pol_none, params, toks[:, i:i+1], c,
            jnp.full((2,), i, jnp.int32))

    dshape = ShapeSpec("d", "decode", 16, 2)
    dstep = make_decode_step(cfg, dshape, mesh)
    in_sh = named_shardings(mesh, dstep.in_specs)
    out_sh = named_shardings(mesh, dstep.out_specs)
    with mesh_context(mesh):
        fn = jax.jit(dstep.fn, in_shardings=in_sh, out_shardings=out_sh)
        c2 = jax.device_put(model.init_cache(2, 16), in_sh[1])
        p2 = jax.device_put(params, in_sh[0])
        for i in range(8):
            logits_sh, c2 = fn(
                p2, c2,
                jax.device_put(toks[:, i:i+1], in_sh[2]),
                jax.device_put(jnp.full((2,), i, jnp.int32), in_sh[3]))
    # TP splits contractions and the partial-softmax combine reorders
    # reductions — bf16 drift is expected; argmax must agree exactly.
    np.testing.assert_allclose(np.asarray(logits_sh),
                               np.asarray(logits_ref), rtol=0.1, atol=0.35)
    assert (np.argmax(np.asarray(logits_sh), -1)
            == np.argmax(np.asarray(logits_ref), -1)).all()
    print("decode-shard-ok")

    # ---- train: one sharded train step == one unsharded step ----
    from repro.optim import adamw, compression
    tshape = ShapeSpec("t", "train", 16, 4)
    tstep = make_train_step(cfg, tshape, mesh, microbatches=2,
                            peak_lr=1e-3, warmup_steps=0, total_steps=10)
    tstep_ref = make_train_step(cfg, tshape, None, microbatches=2,
                                peak_lr=1e-3, warmup_steps=0, total_steps=10)
    params32 = model.init(jax.random.key(1))
    state = {"params": params32, "opt": adamw.init(params32),
             "ef": compression.init_error_feedback(params32)}
    batch = {"tokens": jnp.asarray(rng.integers(0, 256, (4, 16)), jnp.int32),
             "labels": jnp.asarray(rng.integers(0, 256, (4, 16)), jnp.int32)}
    new_ref, m_ref = jax.jit(tstep_ref.fn)(state, batch)
    in_sh = named_shardings(mesh, tstep.in_specs)
    out_sh = named_shardings(mesh, tstep.out_specs)
    with mesh_context(mesh):
        fns = jax.jit(tstep.fn, in_shardings=in_sh, out_shardings=out_sh)
        new_sh, m_sh = fns(jax.device_put(state, in_sh[0]),
                           jax.device_put(batch, in_sh[1]))
    np.testing.assert_allclose(float(m_sh["nll"]), float(m_ref["nll"]),
                               rtol=2e-2)
    # Adam at step 1 normalizes by sqrt(v)≈|g|: bf16 grad noise becomes
    # O(lr)-scale update differences (same bound as test_steps.py).
    w_ref = np.asarray(new_ref["params"]["layers"]["b0_attn_mlp"]["attn"]["wq"])
    w_sh = np.asarray(new_sh["params"]["layers"]["b0_attn_mlp"]["attn"]["wq"])
    np.testing.assert_allclose(w_sh, w_ref, rtol=0.5, atol=4e-3)
    print("train-shard-ok")

    # ---- MoE: shard_map engine == local engine ----
    from repro.models.moe import init_moe, moe_block
    pm = init_moe(jax.random.key(3), 32, 16, 4, n_shared=0)
    xm = jnp.asarray(rng.normal(size=(4, 8, 32)) * 0.3, jnp.float32)
    out_ref, aux_ref = moe_block(pm, xm, top_k=2, capacity_factor=1.5,
                                 policy=Policy.none())
    with mesh_context(mesh):
        pol = Policy.for_mesh(mesh)
        pm_sh = jax.device_put(pm, NamedSharding(mesh, P()))
        fn = jax.jit(lambda p, x: moe_block(
            p, x, top_k=2, capacity_factor=1.5, policy=pol))
        out_sh, aux_sh = fn(pm_sh, jax.device_put(
            xm, NamedSharding(mesh, P("data", None, None))))
    np.testing.assert_allclose(np.asarray(out_sh), np.asarray(out_ref),
                               rtol=2e-3, atol=2e-4)
    np.testing.assert_allclose(float(aux_sh), float(aux_ref), rtol=1e-4)
    print("moe-shard-ok")

    # ---- GPipe pipeline schedule == sequential stack ----
    from repro.models.pipeline import gpipe_apply
    S, M, mb2, dpp = 2, 6, 2, 16
    Ws = jnp.asarray(np.random.default_rng(1).normal(size=(S, dpp, dpp)) * 0.3,
                     jnp.float32)
    xpp = jnp.asarray(np.random.default_rng(2).normal(size=(M, mb2, dpp)),
                      jnp.float32)
    stage = lambda W, x: jnp.tanh(x @ W)
    ref = xpp
    for si in range(S):
        ref = jax.vmap(lambda xm: stage(Ws[si], xm))(ref)
    with mesh_context(mesh):
        outpp = jax.jit(lambda p, xx: gpipe_apply(
            stage, p, xx, mesh=mesh, axis="data"))(Ws, xpp)
    np.testing.assert_allclose(np.asarray(outpp), np.asarray(ref),
                               rtol=1e-5, atol=1e-6)
    print("gpipe-ok")

    # ---- TM: clause-sharded bundle scores == local scores ----
    # (the full registry-driven engine/train parity matrix lives in
    #  tests/test_tm_sharded.py; this is the cross-stack smoke check)
    from repro.core import TMConfig, scores
    from repro.core.distributed import make_sharded_prepare, make_sharded_scores
    tmc = TMConfig(n_classes=4, n_clauses=32, n_features=24, n_states=40)
    rng2 = np.random.default_rng(7)
    ta = jnp.asarray(rng2.integers(1, 81, (4, 32, 48)), jnp.int16)
    xs = jnp.asarray(rng2.integers(0, 2, (8, 24)), jnp.uint8)
    from repro.core.types import TMState
    want = scores(tmc, TMState(ta_state=ta), xs)
    bundle = make_sharded_prepare(tmc, mesh)(TMState(ta_state=ta))
    got = make_sharded_scores(tmc, mesh, engine="dense")(bundle, xs)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    print("tm-shard-ok")
""")


@pytest.mark.slow
def test_sharded_equivalence_subprocess():
    res = subprocess.run(
        [sys.executable, "-c", SCRIPT],
        env={"PYTHONPATH": SRC, "PATH": "/usr/bin:/bin",
             "HOME": "/root"},
        capture_output=True, text=True, timeout=900)
    assert res.returncode == 0, res.stdout + "\n" + res.stderr
    for marker in ("decode-shard-ok", "train-shard-ok", "moe-shard-ok",
                   "gpipe-ok", "tm-shard-ok"):
        assert marker in res.stdout, res.stdout + "\n" + res.stderr
