"""TM forward/learning semantics vs the pure-numpy oracle (paper §2)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    TMConfig, TMState, clause_votes, dense_clause_outputs, init_tm, predict,
    scores, update_batch_parallel, update_batch_sequential, update_sample,
)
from repro.core import ref
from repro.core import tm as tm_mod
from repro.core.types import literals_from_input

CFG = TMConfig(n_classes=3, n_clauses=8, n_features=6, n_states=50,
               s=3.0, threshold=4)


def random_state(cfg, seed=0):
    rng = np.random.default_rng(seed)
    ta = rng.integers(1, 2 * cfg.n_states + 1,
                      (cfg.n_classes, cfg.n_clauses, cfg.n_literals))
    return TMState(ta_state=jnp.asarray(ta, jnp.int16))


@pytest.mark.parametrize("seed", range(4))
@pytest.mark.parametrize("empty_output", [0, 1])
def test_dense_clause_outputs_match_ref(seed, empty_output):
    state = random_state(CFG, seed)
    rng = np.random.default_rng(100 + seed)
    xs = rng.integers(0, 2, (5, CFG.n_features)).astype(np.uint8)
    got = dense_clause_outputs(CFG, state, jnp.asarray(xs),
                               empty_output=empty_output)
    for b in range(xs.shape[0]):
        want = ref.clause_outputs_ref(np.asarray(state.ta_state), xs[b],
                                      CFG.n_states, empty_output)
        np.testing.assert_array_equal(np.asarray(got[b]), want)


def test_votes_match_ref():
    state = random_state(CFG, 7)
    rng = np.random.default_rng(7)
    xs = rng.integers(0, 2, (4, CFG.n_features)).astype(np.uint8)
    out = dense_clause_outputs(CFG, state, jnp.asarray(xs))
    votes = clause_votes(CFG, out)
    for b in range(4):
        want = ref.votes_ref(np.asarray(out[b]))
        np.testing.assert_array_equal(np.asarray(votes[b]), want)


@pytest.mark.parametrize("positive_round", [True, False])
@pytest.mark.parametrize("seed", range(3))
def test_class_round_matches_ref(positive_round, seed):
    """Feedback with injected uniforms is bit-exact vs the numpy oracle."""
    state = random_state(CFG, seed)
    rng = np.random.default_rng(200 + seed)
    x = rng.integers(0, 2, CFG.n_features).astype(np.uint8)
    lit = np.concatenate([x, 1 - x]).astype(np.uint8)
    gate_u = rng.uniform(size=CFG.n_clauses)
    t1_u = rng.uniform(size=(CFG.n_clauses, CFG.n_literals))
    rands = tm_mod.FeedbackRands(clause_gate=jnp.asarray(gate_u),
                                 type_i=jnp.asarray(t1_u))
    got = tm_mod._class_round(CFG, state.ta_state[1], jnp.asarray(lit),
                              rands, jnp.asarray(positive_round))
    want = ref.class_round_ref(
        np.asarray(state.ta_state[1]), lit, gate_u, t1_u,
        n_states=CFG.n_states, s=CFG.s, threshold=CFG.threshold,
        half=CFG.n_clauses // 2, positive_round=positive_round)
    np.testing.assert_array_equal(np.asarray(got, np.int64), want)


def test_update_sample_touches_two_classes():
    state = init_tm(CFG)
    x = jnp.asarray(np.random.default_rng(0).integers(0, 2, CFG.n_features),
                    jnp.uint8)
    new = update_sample(CFG, state, x, jnp.asarray(1), jax.random.key(0))
    changed = np.asarray(
        (new.ta_state != state.ta_state).any(axis=(1, 2)))
    assert changed[1]                    # target class updated
    assert changed.sum() <= 2            # at most one negative class


def test_states_stay_in_bounds_and_learning_learns():
    """A separable toy problem: class = x_0. TM should fit it quickly."""
    cfg = TMConfig(n_classes=2, n_clauses=10, n_features=4, n_states=50,
                   s=3.0, threshold=5)
    rng = np.random.default_rng(3)
    xs = rng.integers(0, 2, (256, cfg.n_features)).astype(np.uint8)
    ys = xs[:, 0].astype(np.int32)
    state = init_tm(cfg)
    key = jax.random.key(42)
    fit = jax.jit(lambda s, x, y, k: update_batch_sequential(cfg, s, x, y, k))
    for ep in range(3):
        key, sub = jax.random.split(key)
        state = fit(state, jnp.asarray(xs), jnp.asarray(ys), sub)
    ta = np.asarray(state.ta_state)
    assert ta.min() >= 1 and ta.max() <= 2 * cfg.n_states
    acc = float(tm_mod.accuracy(cfg, state, jnp.asarray(xs), jnp.asarray(ys)))
    assert acc > 0.95, f"TM failed to learn separable toy problem: acc={acc}"


def test_batch_parallel_update_changes_state_and_stays_bounded():
    cfg = CFG
    state = random_state(cfg, 11)
    rng = np.random.default_rng(11)
    xs = jnp.asarray(rng.integers(0, 2, (16, cfg.n_features)), jnp.uint8)
    ys = jnp.asarray(rng.integers(0, cfg.n_classes, 16), jnp.int32)
    new = update_batch_parallel(cfg, state, xs, ys, jax.random.key(5))
    ta = np.asarray(new.ta_state)
    assert ta.min() >= 1 and ta.max() <= 2 * cfg.n_states
    assert (ta != np.asarray(state.ta_state)).any()


def test_predict_shape_and_range():
    state = random_state(CFG, 2)
    xs = jnp.asarray(np.random.default_rng(1).integers(0, 2, (9, CFG.n_features)),
                     jnp.uint8)
    p = predict(CFG, state, xs)
    assert p.shape == (9,)
    assert int(p.min()) >= 0 and int(p.max()) < CFG.n_classes
