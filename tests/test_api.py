"""TMBundle pytree semantics, TsetlinMachine estimator, TMDriver shim."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    TMConfig, TMBundle, TsetlinMachine, bundle_scores, init_bundle,
    registered_engines, train_step, train_step_jit, validate,
)

CFG = TMConfig(n_classes=2, n_clauses=10, n_features=4, n_states=50,
               s=3.0, threshold=5)
ALL_EVENTS = CFG.n_classes * CFG.n_clauses * CFG.n_literals


def toy_data(n=256, seed=3):
    rng = np.random.default_rng(seed)
    xs = rng.integers(0, 2, (n, CFG.n_features)).astype(np.uint8)
    ys = xs[:, 0].astype(np.int32)  # separable: class = x_0
    return jnp.asarray(xs), jnp.asarray(ys)


# ---------------------------------------------------------------------------
# TMBundle pytree
# ---------------------------------------------------------------------------

def test_bundle_is_pytree_with_static_config():
    bundle = init_bundle(CFG)
    leaves, treedef = jax.tree_util.tree_flatten(bundle)
    assert all(isinstance(l, jax.Array) for l in leaves)
    rebuilt = jax.tree_util.tree_unflatten(treedef, leaves)
    assert rebuilt.cfg == CFG  # config rides the treedef, not the leaves
    assert set(rebuilt.caches) == set(bundle.caches)


def test_bundle_survives_tree_map():
    bundle = init_bundle(CFG)
    same = jax.tree_util.tree_map(lambda x: x, bundle)
    assert isinstance(same, TMBundle)
    np.testing.assert_array_equal(np.asarray(same.state.ta_state),
                                  np.asarray(bundle.state.ta_state))


def test_engine_subset_bundle():
    bundle = init_bundle(CFG, engines=("dense", "indexed"))
    # dense is cache-less (needs_cache=False): storing the state under a
    # second key would alias buffers inside the donated pytree
    assert set(bundle.caches) == {"indexed"}
    xs, _ = toy_data(8)
    # engines without a maintained cache still score (prepared on the fly)
    got = bundle_scores(bundle, xs, engine="compact")
    want = bundle_scores(bundle, xs, engine="dense")
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


# ---------------------------------------------------------------------------
# train_step purity / jit
# ---------------------------------------------------------------------------

def test_train_step_is_pure_and_jits():
    bundle = init_bundle(CFG)
    xs, ys = toy_data(16)
    before = np.asarray(bundle.state.ta_state).copy()
    # purity via the non-donating eager function (reading the input after a
    # donating jitted call would crash on accelerator backends — by design)
    out_eager = train_step(bundle, xs, ys, jax.random.key(0),
                           max_events=ALL_EVENTS)
    np.testing.assert_array_equal(before, np.asarray(bundle.state.ta_state))
    assert (np.asarray(out_eager.state.ta_state) != before).any()
    # jitted path: advances state and keeps the index valid
    out = train_step_jit(init_bundle(CFG), xs, ys, jax.random.key(0),
                         max_events=ALL_EVENTS)
    assert (np.asarray(out.state.ta_state) != before).any()
    for name, ok in validate(CFG, out.state, out.index).items():
        assert bool(ok), name


def test_train_step_jit_and_eager_agree():
    bundle = init_bundle(CFG)
    xs, ys = toy_data(8, seed=9)
    key = jax.random.key(7)
    eager = train_step(bundle, xs, ys, key, max_events=ALL_EVENTS)
    jitted = train_step_jit(bundle, xs, ys, key, max_events=ALL_EVENTS)
    np.testing.assert_array_equal(np.asarray(eager.state.ta_state),
                                  np.asarray(jitted.state.ta_state))
    np.testing.assert_array_equal(np.asarray(eager.index.counts),
                                  np.asarray(jitted.index.counts))


# ---------------------------------------------------------------------------
# TsetlinMachine estimator
# ---------------------------------------------------------------------------

def test_estimator_learns_separable_toy():
    xs, ys = toy_data()
    machine = TsetlinMachine(CFG, seed=42).init()
    machine.fit(xs, ys, epochs=3)
    acc = machine.evaluate(xs, ys, engine="indexed")
    assert acc > 0.95, f"estimator failed separable toy: acc={acc}"
    # all engines agree on the trained machine's predictions
    want = np.asarray(machine.predict(xs, engine="dense"))
    for name in registered_engines():
        got = np.asarray(machine.predict(xs, engine=name))
        np.testing.assert_array_equal(got, want, err_msg=name)


def test_estimator_minibatch_fit_and_seeded_reproducibility():
    xs, ys = toy_data(64)
    a = TsetlinMachine(CFG, seed=5).init().fit(xs, ys, epochs=2, batch_size=16)
    b = TsetlinMachine(CFG, seed=5).init().fit(xs, ys, epochs=2, batch_size=16)
    np.testing.assert_array_equal(np.asarray(a.state.ta_state),
                                  np.asarray(b.state.ta_state))


def test_estimator_checkpoint_roundtrip():
    xs, ys = toy_data(32)
    machine = TsetlinMachine(CFG, seed=1).init().fit(xs, ys)
    tree = machine.as_pytree()
    restored = TsetlinMachine(CFG).load_pytree(
        jax.tree_util.tree_map(jnp.asarray, tree))
    np.testing.assert_array_equal(
        np.asarray(restored.predict(xs, engine="indexed")),
        np.asarray(machine.predict(xs, engine="indexed")))
    for name, ok in validate(CFG, restored.state, restored.index).items():
        assert bool(ok), name


def test_estimator_respects_capacity_config():
    cfg = dataclasses.replace(CFG, index_capacity=6, clause_capacity=5)
    bundle = init_bundle(cfg)
    assert bundle.index.capacity == 6
    assert bundle.caches["compact"].lit_idx.shape[-1] == 5


# ---------------------------------------------------------------------------
# TMDriver deprecated shim
# ---------------------------------------------------------------------------

def test_driver_shim_deprecation_and_parity():
    from repro.core.driver import TMDriver
    with pytest.warns(DeprecationWarning):
        driver = TMDriver.create(CFG)
    xs, ys = toy_data(32)
    driver.train_batch(xs, ys, jax.random.key(0))
    for name, ok in validate(CFG, driver.state, driver.index).items():
        assert bool(ok), name
    want = np.asarray(driver.scores(xs, engine="dense"))
    for name in registered_engines():
        np.testing.assert_array_equal(
            np.asarray(driver.scores(xs, engine=name)), want, err_msg=name)
    # legacy persistence schema intact
    tree = driver.as_pytree()
    assert set(tree) == {"ta_state", "lists", "counts", "pos"}
    with pytest.warns(DeprecationWarning):
        restored = TMDriver.create(CFG).load_pytree(tree)
    np.testing.assert_array_equal(
        np.asarray(restored.predict(xs, engine="indexed")),
        np.asarray(driver.predict(xs, engine="indexed")))


def test_driver_shim_sync_index_false_keeps_other_engines_fresh():
    """Legacy semantics: sync_index=False leaves only the *index* stale;
    bitpack/compact/dense always evaluate off the current state."""
    import warnings
    from repro.core.driver import TMDriver
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        driver = TMDriver.create(CFG)
    xs, ys = toy_data(32)
    driver.train_batch(xs, ys, jax.random.key(3), sync_index=False)
    want = np.asarray(driver.scores(xs, engine="dense"))
    for name in ("bitpack", "bitpack_xla", "compact"):
        np.testing.assert_array_equal(
            np.asarray(driver.scores(xs, engine=name)), want, err_msg=name)
    # the index is stale by request; rebuild restores parity
    driver.rebuild_index()
    np.testing.assert_array_equal(
        np.asarray(driver.scores(xs, engine="indexed")), want)
