"""TMBundle pytree semantics, TsetlinMachine estimator, session checkpoints."""
import dataclasses
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    TMConfig, TMBundle, TsetlinMachine, Topology, bundle_scores, init_bundle,
    registered_engines, train_step, train_step_jit, validate,
)

CFG = TMConfig(n_classes=2, n_clauses=10, n_features=4, n_states=50,
               s=3.0, threshold=5)
ALL_EVENTS = CFG.n_classes * CFG.n_clauses * CFG.n_literals


def toy_data(n=256, seed=3):
    rng = np.random.default_rng(seed)
    xs = rng.integers(0, 2, (n, CFG.n_features)).astype(np.uint8)
    ys = xs[:, 0].astype(np.int32)  # separable: class = x_0
    return jnp.asarray(xs), jnp.asarray(ys)


# ---------------------------------------------------------------------------
# TMBundle pytree
# ---------------------------------------------------------------------------

def test_bundle_is_pytree_with_static_config():
    bundle = init_bundle(CFG)
    leaves, treedef = jax.tree_util.tree_flatten(bundle)
    assert all(isinstance(l, jax.Array) for l in leaves)
    rebuilt = jax.tree_util.tree_unflatten(treedef, leaves)
    assert rebuilt.cfg == CFG  # config rides the treedef, not the leaves
    assert set(rebuilt.caches) == set(bundle.caches)


def test_bundle_survives_tree_map():
    bundle = init_bundle(CFG)
    same = jax.tree_util.tree_map(lambda x: x, bundle)
    assert isinstance(same, TMBundle)
    np.testing.assert_array_equal(np.asarray(same.state.ta_state),
                                  np.asarray(bundle.state.ta_state))


def test_engine_subset_bundle():
    bundle = init_bundle(CFG, engines=("dense", "indexed"))
    # dense is cache-less (needs_cache=False): storing the state under a
    # second key would alias buffers inside the donated pytree
    assert set(bundle.caches) == {"indexed"}
    xs, _ = toy_data(8)
    # engines without a maintained cache still score (prepared on the fly)
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", RuntimeWarning)
        got = bundle_scores(bundle, xs, engine="compact")
    want = bundle_scores(bundle, xs, engine="dense")
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_bundle_scores_warns_once_on_missing_cache_slot():
    """A missing cache slot rebuilds on the fly — with exactly one warning
    per slot, so the per-call rebuild cost can't hide silently."""
    from repro.core import api
    api._REBUILD_WARNED.discard("compact")  # fresh slate for this slot
    bundle = init_bundle(CFG, engines=("indexed",))
    xs, _ = toy_data(6)
    with pytest.warns(RuntimeWarning, match="compact.*rebuilding"):
        bundle_scores(bundle, xs, engine="compact")
    with warnings.catch_warnings():  # second call: silent (warned once)
        warnings.simplefilter("error", RuntimeWarning)
        bundle_scores(bundle, xs, engine="compact")


def test_bundle_scores_reuses_maintained_cache():
    """Regression: a maintained cache must actually be *read*, not silently
    rebuilt from state — probe with a bundle whose cache and state disagree;
    the scores must follow the cache."""
    from repro.core.engines import get_engine
    from repro.core.types import TMState
    rng = np.random.default_rng(0)
    inc = rng.uniform(size=(CFG.n_classes, CFG.n_clauses,
                            CFG.n_literals)) < 0.4
    state_a = TMState(ta_state=jnp.asarray(
        np.where(inc, CFG.n_states + 1, CFG.n_states), jnp.int16))
    cache_a = get_engine("compact").prepare(CFG, state_a)
    blank = init_bundle(CFG, engines=("dense",))  # untrained state
    probe = TMBundle(cfg=CFG, state=blank.state, caches={"compact": cache_a})
    xs, _ = toy_data(8)
    got = np.asarray(bundle_scores(probe, xs, engine="compact"))
    from_cache = np.asarray(
        get_engine("compact").scores(CFG, cache_a, xs))
    from_state = np.asarray(bundle_scores(blank, xs, engine="dense"))
    np.testing.assert_array_equal(got, from_cache)
    assert (got != from_state).any(), \
        "probe degenerate: cache and state scores coincide"


# ---------------------------------------------------------------------------
# train_step purity / jit
# ---------------------------------------------------------------------------

def test_train_step_is_pure_and_jits():
    bundle = init_bundle(CFG)
    xs, ys = toy_data(16)
    before = np.asarray(bundle.state.ta_state).copy()
    # purity via the non-donating eager function (reading the input after a
    # donating jitted call would crash on accelerator backends — by design)
    out_eager = train_step(bundle, xs, ys, jax.random.key(0),
                           max_events=ALL_EVENTS)
    np.testing.assert_array_equal(before, np.asarray(bundle.state.ta_state))
    assert (np.asarray(out_eager.state.ta_state) != before).any()
    # jitted path: advances state and keeps the index valid
    out = train_step_jit(init_bundle(CFG), xs, ys, jax.random.key(0),
                         max_events=ALL_EVENTS)
    assert (np.asarray(out.state.ta_state) != before).any()
    for name, ok in validate(CFG, out.state, out.index).items():
        assert bool(ok), name


def test_train_step_jit_and_eager_agree():
    bundle = init_bundle(CFG)
    xs, ys = toy_data(8, seed=9)
    key = jax.random.key(7)
    eager = train_step(bundle, xs, ys, key, max_events=ALL_EVENTS)
    jitted = train_step_jit(bundle, xs, ys, key, max_events=ALL_EVENTS)
    np.testing.assert_array_equal(np.asarray(eager.state.ta_state),
                                  np.asarray(jitted.state.ta_state))
    np.testing.assert_array_equal(np.asarray(eager.index.counts),
                                  np.asarray(jitted.index.counts))


def test_train_step_mask_ignores_padding_rows():
    """Masked-out rows must not influence the update — padding with zeros or
    with garbage gives bit-identical states; an unmasked garbage row does
    not (the mask is load-bearing)."""
    xs, ys = toy_data(8, seed=4)
    garbage_x = jnp.ones_like(xs[:3])
    garbage_y = jnp.ones_like(ys[:3])
    mask = jnp.arange(11) < 8
    key = jax.random.key(5)
    for parallel in (False, True):
        a = train_step(init_bundle(CFG),
                       jnp.concatenate([xs, jnp.zeros_like(garbage_x)]),
                       jnp.concatenate([ys, jnp.zeros_like(garbage_y)]),
                       key, mask, parallel=parallel, max_events=ALL_EVENTS)
        b = train_step(init_bundle(CFG),
                       jnp.concatenate([xs, garbage_x]),
                       jnp.concatenate([ys, garbage_y]),
                       key, mask, parallel=parallel, max_events=ALL_EVENTS)
        np.testing.assert_array_equal(np.asarray(a.state.ta_state),
                                      np.asarray(b.state.ta_state),
                                      err_msg=f"parallel={parallel}")
        c = train_step(init_bundle(CFG),
                       jnp.concatenate([xs, garbage_x]),
                       jnp.concatenate([ys, garbage_y]),
                       key, jnp.ones(11, bool), parallel=parallel,
                       max_events=ALL_EVENTS)
        assert (np.asarray(c.state.ta_state)
                != np.asarray(a.state.ta_state)).any(), \
            f"parallel={parallel}: garbage rows had no effect unmasked"


# ---------------------------------------------------------------------------
# TsetlinMachine estimator
# ---------------------------------------------------------------------------

def test_estimator_learns_separable_toy():
    xs, ys = toy_data()
    machine = TsetlinMachine(CFG, seed=42).init()
    machine.fit(xs, ys, epochs=3)
    acc = machine.evaluate(xs, ys, engine="indexed")
    assert acc > 0.95, f"estimator failed separable toy: acc={acc}"
    # all engines agree on the trained machine's predictions
    want = np.asarray(machine.predict(xs, engine="dense"))
    for name in registered_engines():
        got = np.asarray(machine.predict(xs, engine=name))
        np.testing.assert_array_equal(got, want, err_msg=name)


def test_estimator_minibatch_fit_and_seeded_reproducibility():
    xs, ys = toy_data(64)
    a = TsetlinMachine(CFG, seed=5).init().fit(xs, ys, epochs=2, batch_size=16)
    b = TsetlinMachine(CFG, seed=5).init().fit(xs, ys, epochs=2, batch_size=16)
    np.testing.assert_array_equal(np.asarray(a.state.ta_state),
                                  np.asarray(b.state.ta_state))


def test_fit_trains_trailing_partial_batch():
    """24 samples at batch_size=16: the trailing 8 pad to the compiled shape
    under a mask — they must train (historically they were dropped), and the
    padded rows must not (zero vs garbage padding is bit-identical)."""
    xs, ys = toy_data(24, seed=8)
    machine = TsetlinMachine(CFG, seed=3).init()
    machine.fit(xs, ys, batch_size=16)

    # reference: the same two steps driven by hand with the same key chain
    ref = TsetlinMachine(CFG, seed=3).init()
    key = ref._next_key(None)
    key, k1 = jax.random.split(key)
    ref.partial_fit(xs[:16], ys[:16], k1, mask=jnp.ones(16, bool))
    key, k2 = jax.random.split(key)
    pad_x = jnp.concatenate([xs[16:], jnp.zeros((8, CFG.n_features),
                                                xs.dtype)])
    pad_y = jnp.concatenate([ys[16:], jnp.zeros((8,), ys.dtype)])
    ref.partial_fit(pad_x, pad_y, k2, mask=jnp.arange(16) < 8)
    np.testing.assert_array_equal(np.asarray(machine.state.ta_state),
                                  np.asarray(ref.state.ta_state))

    # the trailing batch really trained: dropping it changes the state
    dropped = TsetlinMachine(CFG, seed=3).init()
    dkey = dropped._next_key(None)
    dkey, d1 = jax.random.split(dkey)
    dropped.partial_fit(xs[:16], ys[:16], d1, mask=jnp.ones(16, bool))
    assert (np.asarray(machine.state.ta_state)
            != np.asarray(dropped.state.ta_state)).any()


def test_fit_batch_size_larger_than_dataset_raises():
    xs, ys = toy_data(8)
    with pytest.raises(ValueError, match="exceeds dataset size"):
        TsetlinMachine(CFG, seed=0).init().fit(xs, ys, batch_size=16)


def test_estimator_respects_capacity_config():
    cfg = dataclasses.replace(CFG, index_capacity=6, clause_capacity=5)
    bundle = init_bundle(cfg)
    assert bundle.index.capacity == 6
    assert bundle.caches["compact"].lit_idx.shape[-1] == 5


# ---------------------------------------------------------------------------
# Topology + versioned checkpoints (single-device; sharded counterparts in
# tests/test_tm_session.py's forced-multi-device subprocess)
# ---------------------------------------------------------------------------

def test_topology_validates_and_describes():
    t = Topology(clause_shards=2, data_shards=2, engines=["indexed"])
    assert t.engines == ("indexed",)  # normalised to a tuple
    assert t.n_devices == 4 and t.is_sharded
    assert Topology().describe() == {
        "clause_shards": 1, "data_shards": 1, "devices": 1,
        "async_votes": 0}
    with pytest.raises(ValueError, match="must be >= 1"):
        Topology(clause_shards=0)
    with pytest.raises(RuntimeError, match="devices"):
        TsetlinMachine(CFG, topology=Topology(clause_shards=512)).init()


def test_estimator_checkpoint_roundtrip(tmp_path):
    xs, ys = toy_data(32)
    machine = TsetlinMachine(CFG, seed=1).init().fit(xs, ys)
    machine.save(tmp_path / "ck", step=2)
    restored = TsetlinMachine.load(tmp_path / "ck", CFG)
    np.testing.assert_array_equal(
        np.asarray(restored.predict(xs, engine="indexed")),
        np.asarray(machine.predict(xs, engine="indexed")))
    for name, ok in validate(CFG, restored.state, restored.index).items():
        assert bool(ok), name


def test_checkpoint_fingerprint_mismatch_is_clear(tmp_path):
    from repro.checkpoint import CheckpointMismatch
    xs, ys = toy_data(16)
    TsetlinMachine(CFG, seed=1).init().fit(xs, ys).save(tmp_path / "ck")
    # same shapes, different semantics — only the fingerprint can catch it
    other = dataclasses.replace(CFG, s=9.0)
    with pytest.raises(CheckpointMismatch, match="fingerprint mismatch"):
        TsetlinMachine.load(tmp_path / "ck", other)
