"""Asynchronous stale-vote training (``Topology(async_votes=K)``, §11).

Fast units (no devices needed): topology validation + describe metadata,
the per-shard row census, and the bundle pytree carrying ``vote_acc``.

Forced-4-device subprocess (``@slow``), covering the ISSUE-7 gates:

  * ``async_votes=0`` is **bit-exact** with today's synchronous sharded
    path (and with the single-device reference) in both learning modes;
  * ``async_votes=K>0`` reaches **accuracy parity** with sync training on
    MNIST-scale synthetic data in both learning modes (xla backend), and
    the async trajectory itself is **bit-exact across kernel backends**
    (xla vs pallas_interpret) — together covering "both modes, both
    backends" without training through the Python-interpreted kernels;
  * checkpoint round-trip **across topologies**: an async-trained state
    saves topology-free, restores onto sync and differently-sharded async
    sessions bit-exactly, and the restored accumulator is fresh zeros
    (rebuildable state — never persisted);
  * the collective arithmetic per K steps: async step HLO = sync − 3
    (two per-round vote psums + the overflow psum removed; zero left on a
    clause-only mesh) and the refresh is exactly one all-reduce;
  * exact ``event_overflow`` accounting: with ``max_events=0`` every
    boundary crossing drops, so the counter must equal the host-side
    crossing count of the *actual* trajectory — sync counts per step,
    async holds the counter frozen between refreshes and drains the
    accumulated window total through the refresh collective.
"""
import subprocess
import sys
import textwrap
from pathlib import Path

import pytest

from repro.core.distributed import clause_geometry
from repro.core.session import Topology

SRC = str(Path(__file__).resolve().parents[1] / "src")


def test_topology_async_votes_validation():
    assert Topology().async_votes == 0
    assert Topology(clause_shards=2, async_votes=4).describe()[
        "async_votes"] == 4
    with pytest.raises(ValueError, match="async_votes"):
        Topology(async_votes=-1)


def test_async_votes_requires_sharded_placement():
    from repro.core import TMConfig, TMSession
    cfg = TMConfig(n_classes=2, n_clauses=4, n_features=4)
    with pytest.raises(ValueError, match="sharded"):
        TMSession(cfg, Topology(async_votes=2))


def test_shard_rows_census():
    # even: no padding anywhere
    g = clause_geometry(16, 4, 1)
    assert g.shard_rows() == [
        {"shard": i, "real_rows": 4, "pad_rows": 0} for i in range(4)]
    # ragged: padding lands entirely on the trailing shard(s)
    g = clause_geometry(10, 4, 1)  # n_local=3 -> rows 3,3,3,1(+2 pad)
    assert g.shard_rows() == [
        {"shard": 0, "real_rows": 3, "pad_rows": 0},
        {"shard": 1, "real_rows": 3, "pad_rows": 0},
        {"shard": 2, "real_rows": 3, "pad_rows": 0},
        {"shard": 3, "real_rows": 1, "pad_rows": 2}]
    assert sum(r["real_rows"] for r in g.shard_rows()) == 10


def test_bundle_pytree_carries_vote_acc():
    import jax
    import jax.numpy as jnp
    from repro.core import TMConfig
    from repro.core.api import init_bundle
    from repro.core.types import VoteAccumulator

    cfg = TMConfig(n_classes=2, n_clauses=4, n_features=4)
    b = init_bundle(cfg, engines=("dense",))
    assert b.vote_acc is None
    leaves, treedef = jax.tree.flatten(b)
    assert jax.tree.unflatten(treedef, leaves).vote_acc is None
    acc = VoteAccumulator(local=jnp.zeros((1, 2), jnp.int32),
                          stale=jnp.zeros((1, 2), jnp.int32),
                          overflow=jnp.zeros((1,), jnp.int32))
    b2 = jax.tree.unflatten(*reversed(jax.tree.flatten(
        type(b)(cfg=cfg, state=b.state, caches=b.caches,
                event_overflow=b.event_overflow, vote_acc=acc))))
    assert isinstance(b2.vote_acc, VoteAccumulator)
    assert b2.vote_acc.local.shape == (1, 2)


SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import dataclasses
    import tempfile
    import jax, jax.numpy as jnp
    import numpy as np

    from repro.core import (
        TMConfig, TMSession, TMState, Topology, init_bundle, train_step)
    from repro.core.distributed import (
        make_sharded_prepare, make_sharded_train_step, make_vote_refresh)
    from repro.core.types import include_mask, init_tm
    from repro.data.synthetic import binarized_images
    from repro.launch import hlo as hlo_mod

    cfg = TMConfig(n_classes=3, n_clauses=16, n_features=12, n_states=50,
                   s=3.0, threshold=4)
    ALL = cfg.n_classes * cfg.n_clauses * cfg.n_literals
    rng = np.random.default_rng(0)
    inc0 = rng.uniform(size=(3, 16, 24)) < 0.4
    state0 = TMState(ta_state=jnp.asarray(
        np.where(inc0, cfg.n_states + 1, cfg.n_states), jnp.int16))

    def batches(n, b, seed=1):
        r = np.random.default_rng(seed)
        key = jax.random.key(seed)
        for _ in range(n):
            key, sub = jax.random.split(key)
            yield (jnp.asarray(r.integers(0, 2, (b, 12)), jnp.uint8),
                   jnp.asarray(r.integers(0, 3, b), jnp.int32), sub)

    # ---- K=0 is bit-exact with the sync sharded path + the reference ----
    for parallel in (False, True):
        sess0 = TMSession(cfg, Topology(clause_shards=4, async_votes=0),
                          parallel=parallel, max_events=ALL)
        sess_sync = TMSession(cfg, Topology(clause_shards=4),
                              parallel=parallel, max_events=ALL)
        b0, bs, ref = (sess0.prepare(state0), sess_sync.prepare(state0),
                       init_bundle(cfg, state=state0))
        for bx, by, sub in batches(3, 8):
            b0 = sess0.train_step(b0, bx, by, sub)
            bs = sess_sync.train_step(bs, bx, by, sub)
            ref = train_step(ref, bx, by, sub, parallel=parallel,
                             max_events=ALL)
        np.testing.assert_array_equal(np.asarray(b0.state.ta_state),
                                      np.asarray(bs.state.ta_state))
        np.testing.assert_array_equal(np.asarray(b0.state.ta_state),
                                      np.asarray(ref.state.ta_state))
        assert b0.vote_acc is None
    print("tm-async-k0-bitexact-ok")

    # ---- K>0 accuracy parity, both learning modes (MNIST-scale) ----
    # benchmark-proven scale (benchmarks/tm_speedup.train_sync_vs_async):
    # 128 clauses / batch 32 converges on this task, so parity is a tight
    # check rather than noise around a half-trained model
    mcfg = TMConfig(n_classes=10, n_clauses=128, n_features=196)
    xs, ys = binarized_images(32 * 36 + 256, 196, 10, seed=3)
    xs, ys = jnp.asarray(xs), jnp.asarray(ys)
    x_ev, y_ev = xs[:256], ys[:256]
    xt, yt = xs[256:], ys[256:]
    for parallel in (False, True):
        accs = {}
        for k in (0, 4):
            sess = TMSession(
                mcfg, Topology(clause_shards=4, async_votes=k,
                               engines=("dense",)), parallel=parallel)
            b = sess.prepare(init_tm(mcfg))
            key = jax.random.key(7)
            for i in range(36):
                key, sub = jax.random.split(key)
                s0 = i * 32
                b = sess.train_step(b, xt[s0:s0+32], yt[s0:s0+32], sub)
            b = sess.refresh_votes(b)
            accs[k] = float(jnp.mean(
                (sess.predict(b, x_ev, engine="dense") == y_ev)
                .astype(jnp.float32)))
        base = float(jnp.mean((y_ev == 0).astype(jnp.float32)))
        assert accs[0] > base + 0.2, (parallel, accs, base)
        assert abs(accs[4] - accs[0]) <= 0.10, (parallel, accs)
        print(f"tm-async-parity parallel={parallel} "
              f"sync={accs[0]:.3f} async={accs[4]:.3f}")
    print("tm-async-accuracy-parity-ok")

    # ---- async trajectory bit-exact across kernel backends ----
    states = {}
    for backend in ("xla", "pallas_interpret"):
        sess = TMSession(cfg, Topology(clause_shards=4, async_votes=2,
                                       backend=backend), max_events=ALL)
        b = sess.prepare(state0)
        for bx, by, sub in batches(4, 8):
            b = sess.train_step(b, bx, by, sub)
        states[backend] = np.asarray(b.state.ta_state)
    np.testing.assert_array_equal(states["xla"], states["pallas_interpret"])
    print("tm-async-backend-bitexact-ok")

    # ---- checkpoint round-trip across topologies: accumulator rebuilt ----
    with tempfile.TemporaryDirectory() as tmp:
        sess_a = TMSession(cfg, Topology(clause_shards=4, async_votes=2),
                           max_events=ALL)
        b = sess_a.prepare(state0)
        for bx, by, sub in batches(3, 8):   # mid-window on purpose
            b = sess_a.train_step(b, bx, by, sub)
        assert b.vote_acc is not None
        assert np.asarray(b.vote_acc.stale).any()  # a refresh happened
        sess_a.save(tmp, b, step=3)
        want = np.asarray(sess_a.unpad_state(b.state).ta_state)
        # restore onto: a sync session, and a differently-sharded async one
        for topo in (Topology(clause_shards=2),
                     Topology(clause_shards=2, data_shards=2,
                              async_votes=8)):
            sess_b = TMSession(cfg, topo, max_events=ALL)
            rb, step = sess_b.restore(tmp)
            assert step == 3
            np.testing.assert_array_equal(
                np.asarray(sess_b.unpad_state(rb.state).ta_state), want)
            if topo.async_votes:
                # rebuildable state: fresh zeros on the new topology
                assert not np.asarray(rb.vote_acc.local).any()
                assert not np.asarray(rb.vote_acc.stale).any()
                assert not np.asarray(rb.vote_acc.overflow).any()
            else:
                assert rb.vote_acc is None
    print("tm-async-checkpoint-roundtrip-ok")

    # ---- collective count per K steps (async = sync - 3; refresh = 1) ----
    from repro.launch.mesh import make_host_mesh
    ccfg = TMConfig(n_classes=3, n_clauses=16, n_features=12)
    for mesh_kw, parallel in ((dict(data=1, model=4), False),
                              (dict(data=2, model=2), False),
                              (dict(data=2, model=2), True)):
        mesh = make_host_mesh(**mesh_kw)
        bundle = make_sharded_prepare(ccfg, mesh, async_votes=4)(
            init_tm(ccfg))
        txs = jnp.zeros((4, ccfg.n_features), jnp.uint8)
        tys = jnp.zeros((4,), jnp.int32)
        tmask = jnp.ones((4,), bool)
        kd = jax.random.key_data(jax.random.key(0))
        counts = {}
        for tag, k in (("sync", 0), ("async", 4)):
            step = make_sharded_train_step(ccfg, mesh, parallel=parallel,
                                           max_events=64, async_votes=k)
            args = ((bundle.state, bundle.caches, step.pol,
                     bundle.vote_acc, txs, tys, kd, tmask) if k else
                    (bundle.state, bundle.caches, step.pol, txs, tys, kd,
                     tmask, jnp.zeros((), jnp.int32)))
            counts[tag] = hlo_mod.collective_stats(
                step.jitted.lower(*args).compile().as_text()).count
        assert counts["async"] == counts["sync"] - 3, (mesh_kw, counts)
        if mesh_kw == dict(data=1, model=4) and not parallel:
            assert counts["async"] == 0, counts
        refresh = make_vote_refresh(ccfg, mesh, parallel=parallel)
        rstats = hlo_mod.collective_stats(
            refresh.jitted.lower(bundle.vote_acc,
                                 jnp.zeros((), jnp.int32))
            .compile().as_text())
        assert rstats.count == 1, rstats.by_kind
        assert set(rstats.by_kind) == {"all-reduce"}, rstats.by_kind
    print("tm-async-collective-count-ok")

    # ---- exact event_overflow accounting (max_events=0 drops all) ----
    def crossings(a, b):
        return int(np.sum(np.asarray(include_mask(cfg, a))
                          != np.asarray(include_mask(cfg, b))))

    for topo in (Topology(clause_shards=4, async_votes=2),
                 Topology(clause_shards=2, data_shards=2, async_votes=2)):
        sess = TMSession(cfg, topo, engines=("dense",), max_events=0)
        sync = TMSession(cfg, dataclasses.replace(topo, async_votes=0),
                         engines=("dense",), max_events=0)
        b, bsync = sess.prepare(state0), sync.prepare(state0)
        expected = 0
        for i, (bx, by, sub) in enumerate(batches(4, 8)):
            prev = sess.unpad_state(b.state)
            b = sess.train_step(b, bx, by, sub)
            expected += crossings(prev, sess.unpad_state(b.state))
            got = int(jax.device_get(b.event_overflow))
            if (i + 1) % topo.async_votes == 0:   # refresh just ran
                assert got == expected, (i, got, expected)
            # sync counts every step exactly
            prev_s = sync.unpad_state(bsync.state)
            bsync = sync.train_step(bsync, bx, by, sub)
            assert int(jax.device_get(bsync.event_overflow)) > 0
        # mid-window freeze: train one more step, counter must not move
        before = int(jax.device_get(b.event_overflow))
        for bx, by, sub in batches(1, 8, seed=9):
            b = sess.train_step(b, bx, by, sub)
        assert int(jax.device_get(b.event_overflow)) == before
        # forced refresh drains the pending window total
        prev = sess.unpad_state(b.state)
        b2 = sess.refresh_votes(b)
        assert int(jax.device_get(b2.event_overflow)) >= before
    print("tm-async-overflow-accounting-ok")
""")


@pytest.mark.slow
def test_tm_async_subprocess():
    res = subprocess.run(
        [sys.executable, "-c", SCRIPT],
        env={"PYTHONPATH": SRC, "PATH": "/usr/bin:/bin", "HOME": "/root"},
        capture_output=True, text=True, timeout=900)
    assert res.returncode == 0, res.stdout + "\n" + res.stderr
    for marker in ("tm-async-k0-bitexact-ok",
                   "tm-async-accuracy-parity-ok",
                   "tm-async-backend-bitexact-ok",
                   "tm-async-checkpoint-roundtrip-ok",
                   "tm-async-collective-count-ok",
                   "tm-async-overflow-accounting-ok"):
        assert marker in res.stdout, res.stdout + "\n" + res.stderr
