"""Fault tolerance: failure injection → restart → bit-exact continuation;
straggler detection; deterministic data pipeline."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.checkpointer import Checkpointer
from repro.data.pipeline import Prefetcher, TokenBatcher
from repro.runtime.trainer import (
    SimulatedFailure, Trainer, TrainLoopConfig)


def toy_step():
    """A tiny deterministic 'training' step: state = {w, step}."""
    @jax.jit
    def step_fn(state, batch):
        g = jnp.mean(batch["tokens"].astype(jnp.float32))
        w = state["w"] - 0.01 * g
        return {"w": w, "step": state["step"] + 1}, {"loss": g}
    return step_fn


def make_trainer(tmp_path, total, failure_at=None):
    batcher = TokenBatcher(vocab=97, batch=4, seq=8, seed=5)
    return Trainer(
        step_fn=toy_step(),
        state={"w": jnp.zeros((4,)), "step": jnp.asarray(0)},
        batcher=batcher,
        checkpointer=Checkpointer(tmp_path, keep=10),
        loop=TrainLoopConfig(total_steps=total, ckpt_every=5, log_every=1,
                             failure_at=failure_at),
    )


def test_failure_restart_bit_exact(tmp_path):
    # uninterrupted reference run
    ref = make_trainer(tmp_path / "ref", 20)
    ref.run()
    ref_w = np.asarray(ref.state["w"])

    # crash at step 12, then restart from the step-10 checkpoint
    tr = make_trainer(tmp_path / "ft", 20, failure_at=12)
    with pytest.raises(SimulatedFailure):
        tr.run()
    tr2 = make_trainer(tmp_path / "ft", 20)       # fresh process, same dir
    resumed_from = tr2.restore_if_available()
    assert resumed_from == 10
    tr2.run(start_step=resumed_from)
    np.testing.assert_array_equal(np.asarray(tr2.state["w"]), ref_w)
    assert int(tr2.state["step"]) == int(ref.state["step"])


def test_straggler_detection(tmp_path):
    tr = make_trainer(tmp_path, 15)
    import time
    real_fn = tr.step_fn

    calls = {"n": 0}
    def slow_fn(state, batch):
        calls["n"] += 1
        if calls["n"] == 12:
            time.sleep(0.3)                        # inject a straggler
        return real_fn(state, batch)
    tr.step_fn = slow_fn
    tr.run()
    assert len(tr.stragglers) >= 1
    assert tr.stragglers[0][0] == 11               # 0-based step index


def test_data_pipeline_determinism_and_sharding():
    b0 = TokenBatcher(vocab=101, batch=8, seq=16, seed=1)
    b1 = TokenBatcher(vocab=101, batch=8, seq=16, seed=1)
    x0, x1 = b0(3), b1(3)
    np.testing.assert_array_equal(x0["tokens"], x1["tokens"])
    np.testing.assert_array_equal(x0["labels"], x1["labels"])
    # labels are next-token shifted
    np.testing.assert_array_equal(x0["tokens"][:, 1:], x0["labels"][:, :-1])
    # shards differ and are batch/shard_count sized
    s0 = TokenBatcher(vocab=101, batch=8, seq=16, seed=1,
                      shard_index=0, shard_count=2)(0)
    s1 = TokenBatcher(vocab=101, batch=8, seq=16, seed=1,
                      shard_index=1, shard_count=2)(0)
    assert s0["tokens"].shape == (4, 16)
    assert not np.array_equal(s0["tokens"], s1["tokens"])


def test_prefetcher_orders_steps():
    batcher = TokenBatcher(vocab=31, batch=2, seq=4, seed=9)
    pf = Prefetcher(batcher, start_step=5, depth=2)
    it = iter(pf)
    got = [next(it) for _ in range(4)]
    pf.close()
    assert [s for s, _ in got] == [5, 6, 7, 8]
    np.testing.assert_array_equal(got[0][1]["tokens"], batcher(5)["tokens"])
