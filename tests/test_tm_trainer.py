"""TM bundles through the fault-tolerant trainer + the serving loop.

Single-device tier-1 coverage (the sharded counterparts live in the
tests/test_tm_sharded.py subprocess): crash → restart from the newest
committed checkpoint → bit-exact continuation of TA state *and* engine
caches; deterministic (seed, step) TM batch stream; batched serving stats.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.checkpointer import Checkpointer
from repro.core import TMConfig, registered_engines, validate
from repro.core.api import bundle_scores
from repro.data.pipeline import TMBatcher
from repro.runtime.tm_task import make_tm_task
from repro.runtime.trainer import SimulatedFailure, Trainer, TrainLoopConfig

CFG = TMConfig(n_classes=3, n_clauses=8, n_features=6, n_states=50,
               s=3.0, threshold=4)
ALL_EVENTS = CFG.n_classes * CFG.n_clauses * CFG.n_literals


def build_trainer(tmp_path, total, failure_at=None):
    task = make_tm_task(CFG, batch=8, seed=2, data_seed=9,
                        max_events=ALL_EVENTS)
    return Trainer(
        step_fn=task.step_fn, state=task.state, batcher=task.batcher,
        checkpointer=Checkpointer(tmp_path, keep=10),
        loop=TrainLoopConfig(total_steps=total, ckpt_every=4, log_every=2,
                             failure_at=failure_at),
        to_ckpt=task.to_ckpt, from_ckpt=task.from_ckpt)


def test_tm_failure_restart_bit_exact(tmp_path):
    ref = build_trainer(tmp_path / "ref", 10)
    ref.run()
    ref_ta = np.asarray(ref.state["bundle"].state.ta_state)

    tr = build_trainer(tmp_path / "ft", 10, failure_at=6)
    with pytest.raises(SimulatedFailure):
        tr.run()
    tr2 = build_trainer(tmp_path / "ft", 10)      # fresh process, same dir
    resumed = tr2.restore_if_available()
    assert resumed == 4
    tr2.run(start_step=resumed)

    np.testing.assert_array_equal(
        np.asarray(tr2.state["bundle"].state.ta_state), ref_ta)
    assert int(tr2.state["step"]) == int(ref.state["step"]) == 10
    # caches were *rebuilt* on restore, then event-synced over steps 4..10 —
    # they must still mirror the state (index invariants + score parity)
    bundle = tr2.state["bundle"]
    for name, ok in validate(CFG, bundle.state, bundle.index).items():
        assert bool(ok), name
    xs = jnp.asarray(np.random.default_rng(5).integers(0, 2, (7, 6)),
                     jnp.uint8)
    want = np.asarray(bundle_scores(bundle, xs, engine="dense"))
    for name in registered_engines():
        np.testing.assert_array_equal(
            np.asarray(bundle_scores(bundle, xs, engine=name)), want,
            err_msg=name)


def test_tm_trainer_learns(tmp_path):
    tr = build_trainer(tmp_path, 12)
    tr.run()
    accs = [m["acc"] for _, m in tr.metrics_log]
    # online accuracy on the toy stream ends high and never collapses
    # (the first logged point is already 2 steps in, so no strict-increase)
    assert accs[-1] >= accs[0]
    assert accs[-1] >= 0.6


def test_tm_batcher_determinism_and_sharding():
    b0 = TMBatcher(6, 3, 8, seed=1)
    b1 = TMBatcher(6, 3, 8, seed=1)
    np.testing.assert_array_equal(b0(4)["x"], b1(4)["x"])
    np.testing.assert_array_equal(b0(4)["y"], b1(4)["y"])
    assert b0(4)["x"].shape == (8, 6) and b0(4)["x"].dtype == np.uint8
    assert not np.array_equal(b0(4)["x"], b0(5)["x"])
    # shards are contiguous row blocks composing back to the global batch
    full = b0(3)
    s0 = TMBatcher(6, 3, 8, seed=1, shard_index=0, shard_count=2)(3)
    s1 = TMBatcher(6, 3, 8, seed=1, shard_index=1, shard_count=2)(3)
    np.testing.assert_array_equal(np.concatenate([s0["x"], s1["x"]]),
                                  full["x"])
    np.testing.assert_array_equal(np.concatenate([s0["y"], s1["y"]]),
                                  full["y"])


def test_tm_serve_smoke_record():
    from repro.launch.tm_serve import ServePolicy, run

    record = run(TMConfig(n_classes=3, n_clauses=16, n_features=12),
                 engines=("indexed", "bitpack_xla"), n_requests=40,
                 rps=5000.0, policy=ServePolicy(max_batch=8))
    assert set(record["engines"]) == {"indexed", "bitpack_xla"}
    for r in record["engines"].values():
        assert r["requests"] == 40
        lat = r["latency_ms"]
        assert lat["p50"] <= lat["p95"] <= lat["p99"] <= lat["max"]
        assert r["throughput_rps"] > 0
        assert 0 < r["padding_efficiency"] <= 1
