"""Engine registry: parity across all registered engines, cache sync.

Driven through ``registered_engines()`` so any newly registered engine is
covered automatically — the paper's core claim (same predictions, less work)
becomes a standing invariant of the registry.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    TMConfig, TMState, bundle_scores, get_engine, init_bundle,
    registered_engines, train_step_jit, validate,
)
from repro.core.engines import cache_provider, packed_include_apply_events
from repro.core.indexing import events_from_transition
from repro.core.types import include_mask

CFG = TMConfig(n_classes=3, n_clauses=8, n_features=6, n_states=50,
               s=3.0, threshold=4, empty_clause_output=1)
ALL_EVENTS = CFG.n_classes * CFG.n_clauses * CFG.n_literals


def random_state(cfg, seed=0, density=0.4):
    rng = np.random.default_rng(seed)
    inc = rng.uniform(
        size=(cfg.n_classes, cfg.n_clauses, cfg.n_literals)) < density
    ta = np.where(inc, cfg.n_states + 1, cfg.n_states)
    return TMState(ta_state=jnp.asarray(ta, jnp.int16))


def random_inputs(cfg, seed, batch=7):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.integers(0, 2, (batch, cfg.n_features)), jnp.uint8)


# ---------------------------------------------------------------------------
# Parity: every registered engine ≡ dense (paper Eq. 4 mode)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", registered_engines())
@pytest.mark.parametrize("seed", range(3))
def test_engine_scores_equal_dense(name, seed):
    state = random_state(CFG, seed)
    xs = random_inputs(CFG, 100 + seed)
    eng = get_engine(name)
    cache = eng.prepare(CFG, state)
    got = eng.scores(CFG, cache, xs)
    want = get_engine("dense").scores(CFG, state, xs)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("name", registered_engines())
def test_engine_argmax_matches_dense(name):
    state = random_state(CFG, 7, density=0.25)
    xs = random_inputs(CFG, 77, batch=9)
    eng = get_engine(name)
    got = jnp.argmax(eng.scores(CFG, eng.prepare(CFG, state), xs), axis=-1)
    want = jnp.argmax(get_engine("dense").scores(CFG, state, xs), axis=-1)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_get_engine_unknown_name():
    with pytest.raises(KeyError):
        get_engine("nope")


# ---------------------------------------------------------------------------
# Parity survives a *jitted* training run with cache maintenance enabled
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("parallel", [False, True])
def test_engine_parity_after_jitted_training(parallel):
    bundle = init_bundle(CFG)
    rng = np.random.default_rng(0)
    key = jax.random.key(0)
    for step in range(3):
        xs = jnp.asarray(rng.integers(0, 2, (12, CFG.n_features)), jnp.uint8)
        ys = jnp.asarray(rng.integers(0, CFG.n_classes, 12), jnp.int32)
        key, sub = jax.random.split(key)
        bundle = train_step_jit(bundle, xs, ys, sub, parallel=parallel,
                                max_events=ALL_EVENTS)
    # the paper's index is still a valid mirror of the state
    for name, ok in validate(CFG, bundle.state, bundle.index).items():
        assert bool(ok), name
    xs = random_inputs(CFG, 999, batch=11)
    want = bundle_scores(bundle, xs, engine="dense")
    for name in registered_engines():
        got = bundle_scores(bundle, xs, engine=name)
        np.testing.assert_array_equal(
            np.asarray(got), np.asarray(want), err_msg=name)


# ---------------------------------------------------------------------------
# Incremental cache maintenance ≡ rebuild, per provider
# ---------------------------------------------------------------------------

def _transition_events(seed):
    s0 = random_state(CFG, seed)
    s1 = random_state(CFG, 50 + seed)
    ev = events_from_transition(include_mask(CFG, s0),
                                include_mask(CFG, s1), ALL_EVENTS).events
    return s0, s1, ev


@pytest.mark.parametrize("seed", range(3))
def test_packed_cache_events_equal_repack(seed):
    s0, s1, ev = _transition_events(seed)
    prov = cache_provider("bitpack")
    got = packed_include_apply_events(prov.prepare(CFG, s0), ev)
    want = prov.prepare(CFG, s1)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("key", ["dense", "bitpack", "compact", "indexed"])
def test_update_cache_matches_prepare_scores(key):
    """Provider-level contract: update_cache(prepare(s0), events) scores
    identically to prepare(s1), for every distinct cache slot."""
    s0, s1, ev = _transition_events(11)
    prov = cache_provider(key)
    synced = prov.update_cache(CFG, prov.prepare(CFG, s0), s1, ev)
    xs = random_inputs(CFG, 1234, batch=5)
    eng = get_engine(key)  # cache_key == a registered engine name here
    np.testing.assert_array_equal(
        np.asarray(eng.scores(CFG, synced, xs)),
        np.asarray(eng.scores(CFG, prov.prepare(CFG, s1), xs)))
