"""Topology-transparent TMSession parity on a forced 8-device host mesh.

The acceptance property of the session API (subprocess,
``--xla_force_host_platform_device_count=8``):

  * the *same estimator script* under ``Topology(1 device)``,
    ``Topology(clause_shards=4)``,
    ``Topology(data_shards=2, clause_shards=2)`` and the **ragged**
    ``Topology(data_shards=3, clause_shards=2)`` (per-shard clause count 8
    does not divide by 3 — composed via zero-padded sub-slices, DESIGN.md
    §9) produces identical predictions and bit-identical TA states for the
    same seed, in both learning modes — including a trailing partial batch
    padded under a sample mask (sequential mode exercises the hierarchical
    data×clause composition; parallel mode the batch sharding);
  * a versioned checkpoint written under one topology (4 clause shards)
    restores bit-exactly under others (1 device, 2×2, then the ragged
    3×2) and a checkpoint written under the ragged topology restores
    bit-exactly on one device — caches rebuilt on the restoring topology,
    state resharded (and padding stripped) on load;
  * event-overflow accounting is placement-independent: with a zero-sized
    buffer the overflow counter equals the exact global crossing count on
    the single-device and the ragged topology alike;
  * restoring with a semantically different config (same shapes) fails with
    the config-fingerprint error, not a shape complaint.
"""
import subprocess
import sys
import textwrap
from pathlib import Path

import pytest

SRC = str(Path(__file__).resolve().parents[1] / "src")

SCRIPT = textwrap.dedent("""
    import os, tempfile
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import dataclasses
    import jax, jax.numpy as jnp
    import numpy as np

    from repro.core import (
        TMConfig, TMSession, Topology, TsetlinMachine, registered_engines)
    from repro.checkpoint import CheckpointMismatch

    cfg = TMConfig(n_classes=3, n_clauses=16, n_features=12, n_states=50,
                   s=3.0, threshold=4)
    ALL = cfg.n_classes * cfg.n_clauses * cfg.n_literals
    rng = np.random.default_rng(0)
    # 20 samples at batch_size=6 -> the fourth batch pads 4 rows under a
    # mask; 6 divides over every topology's data axis (1, 2 and 3 — batches
    # and eval shapes must divide the mesh data axis in parallel/scores)
    xs = jnp.asarray(rng.integers(0, 2, (20, 12)), jnp.uint8)
    ys = jnp.asarray(rng.integers(0, 3, 20), jnp.int32)
    xe = jnp.asarray(rng.integers(0, 2, (6, 12)), jnp.uint8)

    TOPOLOGIES = {
        "single": Topology(),
        "clause4": Topology(clause_shards=4),
        "data2xclause2": Topology(data_shards=2, clause_shards=2),
        # ragged: n_local=8 does not divide by data_shards=3 — previously
        # the silent replication fallback, now composed_ragged (§9)
        "ragged3xclause2": Topology(data_shards=3, clause_shards=2),
    }

    # ---- estimator parity: same script, any placement, both modes ----
    trained = {}
    for parallel in (False, True):
        machines = {}
        for name, topo in TOPOLOGIES.items():
            m = TsetlinMachine(cfg, topology=topo, parallel=parallel,
                               max_events_per_batch=ALL, seed=7).init()
            m.fit(xs, ys, epochs=2, batch_size=6)
            machines[name] = m
        if not parallel:
            d = machines["ragged3xclause2"].session.describe()
            assert d["composition"] == "composed_ragged", d
        ref = machines["single"]
        ref_ta = np.asarray(ref.state.ta_state)
        ref_pred = np.asarray(ref.predict(xe, engine="dense"))
        for name, m in machines.items():
            np.testing.assert_array_equal(
                np.asarray(m.state.ta_state), ref_ta,
                err_msg=f"{name} parallel={parallel}")
            for engine in registered_engines():
                np.testing.assert_array_equal(
                    np.asarray(m.predict(xe, engine=engine)), ref_pred,
                    err_msg=f"{name}/{engine} parallel={parallel}")
        trained[parallel] = machines
    print("tm-session-parity-ok")

    # ---- versioned checkpoint: save on 4 clause shards, load anywhere ----
    tmp = tempfile.mkdtemp()
    saver = trained[False]["clause4"]
    saver.save(tmp + "/ck", step=5)
    want = np.asarray(saver.predict(xe, engine="dense"))
    want_ta = np.asarray(saver.state.ta_state)
    # 4 shards -> 1 -> 2x2 -> the ragged 3x2 (divisible -> ragged)
    for name in ("single", "data2xclause2", "ragged3xclause2"):
        loaded = TsetlinMachine.load(tmp + "/ck", cfg,
                                     topology=TOPOLOGIES[name],
                                     max_events_per_batch=ALL)
        np.testing.assert_array_equal(
            np.asarray(loaded.state.ta_state), want_ta, err_msg=name)
        for engine in registered_engines():
            np.testing.assert_array_equal(
                np.asarray(loaded.predict(xe, engine=engine)), want,
                err_msg=f"restore-{name}/{engine}")
    # ragged -> divisible: padding never leaks into a checkpoint
    trained[False]["ragged3xclause2"].save(tmp + "/ck_ragged", step=5)
    back = TsetlinMachine.load(tmp + "/ck_ragged", cfg,
                               max_events_per_batch=ALL)
    np.testing.assert_array_equal(
        np.asarray(back.state.ta_state),
        np.asarray(trained[False]["ragged3xclause2"].state.ta_state))
    print("tm-session-checkpoint-ok")

    # ---- overflow accounting: exact crossing counts, any placement ----
    # max_events=0 drops every boundary crossing, so the counter must equal
    # the global crossing count — identically on 1 device and ragged shards
    # (per-shard counts psum over the clause axis; padding rows never cross)
    ovf = {}
    for name in ("single", "ragged3xclause2"):
        m0 = TsetlinMachine(cfg, topology=TOPOLOGIES[name],
                            max_events_per_batch=0, seed=7).init()
        m0.partial_fit(xs[:8], ys[:8])
        ovf[name] = m0.event_overflow
    m1 = TsetlinMachine(cfg, topology=Topology(),
                        max_events_per_batch=ALL, seed=7).init()
    before = np.asarray(m1.state.ta_state > cfg.n_states)
    m1.partial_fit(xs[:8], ys[:8])
    crossings = int((before != np.asarray(
        m1.state.ta_state > cfg.n_states)).sum())
    assert ovf["single"] == ovf["ragged3xclause2"] == crossings, (
        ovf, crossings)
    print("tm-session-overflow-ok")

    # ---- fingerprint: same shapes, different semantics -> clear error ----
    other = dataclasses.replace(cfg, threshold=9)
    try:
        TsetlinMachine.load(tmp + "/ck", other)
        raise AssertionError("fingerprint mismatch not detected")
    except CheckpointMismatch as e:
        assert "fingerprint mismatch" in str(e), e
    print("tm-session-fingerprint-ok")
""")


@pytest.mark.slow
def test_tm_session_topology_parity_subprocess():
    res = subprocess.run(
        [sys.executable, "-c", SCRIPT],
        env={"PYTHONPATH": SRC, "PATH": "/usr/bin:/bin", "HOME": "/root"},
        capture_output=True, text=True, timeout=900)
    assert res.returncode == 0, res.stdout + "\n" + res.stderr
    for marker in ("tm-session-parity-ok", "tm-session-checkpoint-ok",
                   "tm-session-overflow-ok", "tm-session-fingerprint-ok"):
        assert marker in res.stdout, res.stdout + "\n" + res.stderr
