"""Clause-sharded TMBundle parity on a forced 8-device host mesh.

Registry-driven (subprocess, ``--xla_force_host_platform_device_count=8``):

  * every registered engine's sharded ``scores`` is bit-exact vs the
    single-device dense reference;
  * the sharded ``train_step`` (sequential *and* batch-parallel) produces a
    bit-exact TA state vs the single-device ``api.train_step``, and every
    engine's shard-local cache stays a faithful mirror (scores parity after
    training proves the event sync);
  * ragged boundaries (DESIGN.md §9): a prime per-shard clause count whose
    data sub-slices carry more padding than real rows on some ranks trains
    and scores bit-exactly (``composed_ragged``), and the
    ``data_shards > n_local`` escape hatch warns, names the ``replicated``
    rule, and stays bit-exact;
  * the fault-tolerant trainer checkpoints a sharded TM bundle, crashes,
    and restores **onto a different mesh** (reshard-on-restore: 4 clause
    shards → 2), continuing bit-exactly vs an uninterrupted single-device
    trainer run.
"""
import subprocess
import sys
import textwrap
from pathlib import Path

import pytest

SRC = str(Path(__file__).resolve().parents[1] / "src")

SCRIPT = textwrap.dedent("""
    import os, tempfile
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp
    import numpy as np

    from repro.core import (
        TMConfig, TMSession, TMState, bundle_scores, init_bundle,
        registered_engines, train_step)
    from repro.core.distributed import (
        make_sharded_prepare, make_sharded_scores, make_sharded_train_step)
    from repro.launch.mesh import make_host_mesh

    cfg = TMConfig(n_classes=3, n_clauses=16, n_features=12, n_states=50,
                   s=3.0, threshold=4)
    ALL = cfg.n_classes * cfg.n_clauses * cfg.n_literals
    rng = np.random.default_rng(0)
    inc = rng.uniform(size=(3, 16, 24)) < 0.4
    state = TMState(ta_state=jnp.asarray(
        np.where(inc, cfg.n_states + 1, cfg.n_states), jnp.int16))
    xs_eval = jnp.asarray(rng.integers(0, 2, (8, 12)), jnp.uint8)

    mesh = make_host_mesh(data=2, model=4)
    ref = init_bundle(cfg, state=state)
    stm = TMSession(cfg, mesh=mesh, max_events=ALL)
    assert stm.describe() == {"clause_shards": 4, "data_shards": 2,
                              "devices": 8, "sharded": True,
                              "backend": "xla", "async_votes": 0,
                              "composition": "composed_even",
                              "shard_rows": [
                                  {"shard": i, "real_rows": 4, "pad_rows": 0}
                                  for i in range(4)]}, stm.describe()
    sb = stm.prepare(state)

    # ---- scores parity: every registered engine, bit-exact vs dense ----
    want = np.asarray(bundle_scores(ref, xs_eval, engine="dense"))
    for name in registered_engines():
        got = np.asarray(stm.scores(sb, xs_eval, engine=name))
        np.testing.assert_array_equal(got, want, err_msg=name)
    print("tm-scores-parity-ok")

    # ---- train parity: both learning modes, caches mirrored ----
    for parallel in (False, True):
        step = make_sharded_train_step(cfg, mesh, parallel=parallel,
                                       max_events=ALL)
        b_ref, b_sh = ref, stm.prepare(state)
        key = jax.random.key(1)
        for _ in range(3):
            key, sub = jax.random.split(key)
            bx = jnp.asarray(rng.integers(0, 2, (8, 12)), jnp.uint8)
            by = jnp.asarray(rng.integers(0, 3, 8), jnp.int32)
            b_ref = train_step(b_ref, bx, by, sub, parallel=parallel,
                               max_events=ALL)
            b_sh = step(b_sh, bx, by, sub)
        np.testing.assert_array_equal(
            np.asarray(b_sh.state.ta_state), np.asarray(b_ref.state.ta_state),
            err_msg=f"parallel={parallel}")
        want2 = np.asarray(bundle_scores(b_ref, xs_eval, engine="dense"))
        for name in registered_engines():
            got2 = np.asarray(stm.scores(b_sh, xs_eval, engine=name))
            np.testing.assert_array_equal(
                got2, want2, err_msg=f"{name} parallel={parallel}")
    print("tm-train-parity-ok")

    # ---- ragged boundaries (DESIGN.md §9) ----
    import warnings

    # prime per-shard clause count with padding > real rows on a rank:
    # n_clauses=14 over model=2 -> n_local=7 (prime); data=3 -> n_sub=3,
    # so the last data rank owns 1 real row + 2 padding rows per shard
    cfg_p = TMConfig(n_classes=3, n_clauses=14, n_features=12, n_states=50,
                     s=3.0, threshold=4)
    ALLP = cfg_p.n_classes * cfg_p.n_clauses * cfg_p.n_literals
    inc_p = rng.uniform(size=(3, 14, 24)) < 0.4
    state_p = TMState(ta_state=jnp.asarray(
        np.where(inc_p, cfg_p.n_states + 1, cfg_p.n_states), jnp.int16))
    mesh_p = make_host_mesh(data=3, model=2)
    stm_p = TMSession(cfg_p, mesh=mesh_p, max_events=ALLP)
    assert stm_p.describe()["composition"] == "composed_ragged", (
        stm_p.describe())
    ref_p = init_bundle(cfg_p, state=state_p)
    b_p = stm_p.prepare(state_p)
    key = jax.random.key(2)
    for _ in range(2):
        key, sub = jax.random.split(key)
        bx = jnp.asarray(rng.integers(0, 2, (6, 12)), jnp.uint8)
        by = jnp.asarray(rng.integers(0, 3, 6), jnp.int32)
        ref_p = train_step(ref_p, bx, by, sub, max_events=ALLP)
        b_p = stm_p.train_step(b_p, bx, by, sub)
    np.testing.assert_array_equal(
        np.asarray(stm_p.unpad_state(b_p.state).ta_state),
        np.asarray(ref_p.state.ta_state))
    # eval batch must divide over the 3-way data axis (scores shard it)
    xe_p = xs_eval[:6]
    want_p = np.asarray(bundle_scores(ref_p, xe_p, engine="dense"))
    for name in registered_engines():
        np.testing.assert_array_equal(
            np.asarray(stm_p.scores(b_p, xe_p, engine=name)), want_p,
            err_msg=f"prime-ragged/{name}")
    print("tm-ragged-prime-ok")

    # escape hatch: data_shards=4 > n_local=3 (n_clauses=6 / model=2) ->
    # warn-and-replicate, naming the fired rule; still bit-exact
    cfg_r = TMConfig(n_classes=3, n_clauses=6, n_features=12, n_states=50,
                     s=3.0, threshold=4)
    ALLR = cfg_r.n_classes * cfg_r.n_clauses * cfg_r.n_literals
    mesh_r = make_host_mesh(data=4, model=2)
    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        stm_r = TMSession(cfg_r, mesh=mesh_r, max_events=ALLR)
    assert stm_r.describe()["composition"] == "replicated", stm_r.describe()
    assert any("'replicated'" in str(w.message)
               and "data_shards=4" in str(w.message) for w in caught), (
        [str(w.message) for w in caught])
    ref_r = init_bundle(cfg_r)
    b_r = stm_r.prepare(ref_r.state)
    key, sub = jax.random.split(key)
    bx = jnp.asarray(rng.integers(0, 2, (6, 12)), jnp.uint8)
    by = jnp.asarray(rng.integers(0, 3, 6), jnp.int32)
    ref_r = train_step(ref_r, bx, by, sub, max_events=ALLR)
    b_r = stm_r.train_step(b_r, bx, by, sub)
    np.testing.assert_array_equal(
        np.asarray(stm_r.unpad_state(b_r.state).ta_state),
        np.asarray(ref_r.state.ta_state))
    print("tm-ragged-replicate-ok")

    # ---- trainer: sharded checkpoint → crash → reshard-on-restore ----
    from repro.checkpoint.checkpointer import Checkpointer
    from repro.runtime.tm_task import make_tm_task
    from repro.runtime.trainer import (
        SimulatedFailure, Trainer, TrainLoopConfig)

    def build(task, ckpt_dir, total, failure_at=None):
        return Trainer(step_fn=task.step_fn, state=task.state,
                       batcher=task.batcher,
                       checkpointer=Checkpointer(ckpt_dir, keep=10),
                       loop=TrainLoopConfig(total_steps=total, ckpt_every=3,
                                            log_every=1,
                                            failure_at=failure_at),
                       to_ckpt=task.to_ckpt, from_ckpt=task.from_ckpt)

    tmp = tempfile.mkdtemp()
    kw = dict(batch=8, seed=3, data_seed=11, max_events=ALL)

    ref_tr = build(make_tm_task(cfg, **kw), tmp + "/ref", 8)
    ref_tr.run()
    ref_ta = np.asarray(ref_tr.state["bundle"].state.ta_state)

    tr = build(make_tm_task(cfg, mesh=mesh, **kw), tmp + "/ft", 8,
               failure_at=5)
    try:
        tr.run()
        raise AssertionError("expected injected failure")
    except SimulatedFailure:
        pass

    mesh2 = make_host_mesh(data=4, model=2)   # different clause-shard count
    tr2 = build(make_tm_task(cfg, mesh=mesh2, **kw), tmp + "/ft", 8)
    resumed = tr2.restore_if_available()
    assert resumed == 3, resumed
    tr2.run(start_step=resumed)
    np.testing.assert_array_equal(
        np.asarray(tr2.state["bundle"].state.ta_state), ref_ta)
    # the rebuilt shard-local caches on mesh2 serve identical scores
    stm2 = TMSession(cfg, mesh=mesh2, max_events=ALL)
    want3 = np.asarray(bundle_scores(ref_tr.state["bundle"], xs_eval,
                                     engine="dense"))
    for name in registered_engines():
        got3 = np.asarray(stm2.scores(tr2.state["bundle"], xs_eval,
                                      engine=name))
        np.testing.assert_array_equal(got3, want3, err_msg=name)
    print("tm-trainer-reshard-ok")
""")


@pytest.mark.slow
def test_tm_sharded_parity_subprocess():
    res = subprocess.run(
        [sys.executable, "-c", SCRIPT],
        env={"PYTHONPATH": SRC, "PATH": "/usr/bin:/bin", "HOME": "/root"},
        capture_output=True, text=True, timeout=900)
    assert res.returncode == 0, res.stdout + "\n" + res.stderr
    for marker in ("tm-scores-parity-ok", "tm-train-parity-ok",
                   "tm-ragged-prime-ok", "tm-ragged-replicate-ok",
                   "tm-trainer-reshard-ok"):
        assert marker in res.stdout, res.stdout + "\n" + res.stderr
