"""Per-arch smoke tests: reduced config, forward + train-step + decode on CPU.

Asserts output shapes, NaN-freeness, and prefill↔decode consistency.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config, reduce_config
from repro.configs.base import ShapeSpec
from repro.models.model import build, effective_cache_len, input_specs
from repro.sharding import Policy

POLICY = Policy.none()
SMOKE_TRAIN = ShapeSpec("smoke_train", "train", 16, 2)
SMOKE_DECODE = ShapeSpec("smoke_decode", "decode", 16, 2)


def _concrete_batch(cfg, shape):
    batch = input_specs(cfg, shape, concrete=True)
    rng = np.random.default_rng(0)
    out = {}
    for k, v in batch.items():
        if v.dtype == jnp.int32:
            hi = cfg.vocab if k in ("tokens", "labels", "token") else 8
            out[k] = jnp.asarray(rng.integers(0, hi, v.shape), jnp.int32)
        else:
            out[k] = jnp.asarray(rng.normal(size=v.shape) * 0.02, v.dtype)
    return out


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_and_finite(arch):
    cfg = reduce_config(get_config(arch))
    model = build(cfg)
    params = model.init(jax.random.key(0)) if cfg.family != "encdec" else (
        model.init(jax.random.key(0), 64))
    batch = _concrete_batch(cfg, SMOKE_TRAIN)
    batch.pop("labels")
    logits, aux = jax.jit(
        lambda p, b: model.apply_train(POLICY, p, **b))(params, batch)
    s_text = SMOKE_TRAIN.seq_len
    if cfg.family == "vlm":
        s_out = SMOKE_TRAIN.seq_len  # vision tokens + text
    else:
        s_out = s_text
    assert logits.shape == (SMOKE_TRAIN.global_batch, s_out, cfg.vocab), (
        logits.shape)
    assert bool(jnp.isfinite(logits).all()), "non-finite logits"
    assert bool(jnp.isfinite(aux)), "non-finite aux loss"


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_reduces_loss_direction(arch):
    """One SGD step on the smoke config must produce finite grads that
    change the loss (sanity of the backward pass through every family)."""
    cfg = reduce_config(get_config(arch))
    model = build(cfg)
    params = model.init(jax.random.key(1)) if cfg.family != "encdec" else (
        model.init(jax.random.key(1), 64))
    batch = _concrete_batch(cfg, SMOKE_TRAIN)
    labels = batch.pop("labels")

    def loss_fn(p):
        logits, aux = model.apply_train(POLICY, p, **batch)
        if cfg.family == "vlm":
            logits = logits[:, cfg.n_vision_tokens:]
        logp = jax.nn.log_softmax(logits, axis=-1)
        nll = -jnp.take_along_axis(logp, labels[..., None], -1).mean()
        return nll + 0.01 * aux

    loss, grads = jax.jit(jax.value_and_grad(loss_fn))(params)
    assert bool(jnp.isfinite(loss)), f"loss={loss}"
    flat = jax.tree.leaves(grads)
    assert all(bool(jnp.isfinite(g).all()) for g in flat), "non-finite grads"
    gnorm = jnp.sqrt(sum(jnp.sum(g.astype(jnp.float32) ** 2) for g in flat))
    assert float(gnorm) > 0, "zero gradient"


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_decode_consistency(arch):
    """Greedy next-token after prefill(S) == next-token after S decode steps.

    This pins cache semantics (rolling windows, recurrent states, rope
    positions) across every family.
    """
    cfg = reduce_config(get_config(arch))
    model = build(cfg)
    params = model.init(jax.random.key(2)) if cfg.family != "encdec" else (
        model.init(jax.random.key(2), 64))
    rng = np.random.default_rng(3)
    b, s = 2, 8
    tokens = jnp.asarray(rng.integers(0, cfg.vocab, (b, s)), jnp.int32)
    extra = {}
    if cfg.family == "vlm":
        extra["vision_embeds"] = jnp.asarray(
            rng.normal(size=(b, cfg.n_vision_tokens, cfg.d_model)) * 0.02,
            jnp.bfloat16)
    if cfg.family == "encdec":
        extra["frames"] = jnp.asarray(
            rng.normal(size=(b, cfg.enc_seq, cfg.d_model)) * 0.02,
            jnp.bfloat16)

    cache_len = 16
    logits_pre, cache_pre = jax.jit(
        lambda p, t: model.prefill(POLICY, p, cache_len, tokens=t, **extra)
    )(params, tokens)

    # decode path: feed tokens one by one from an empty cache
    n_vis = cfg.n_vision_tokens if cfg.family == "vlm" else 0
    if n_vis:
        # decode-only consistency not defined with a vision prefix; prefill
        # handles the prefix. Compare decode continuation instead below.
        logits_pre2, cache2 = jax.jit(
            lambda p, t: model.prefill(POLICY, p, cache_len, tokens=t,
                                       **extra))(params, tokens)
        np.testing.assert_allclose(np.asarray(logits_pre),
                                   np.asarray(logits_pre2), rtol=1e-5)
        return

    if cfg.family == "encdec":
        cache = model.init_cache(b, cache_len)
        # cross-attn KV must come from the same encoder pass → take from
        # a prefill of the first token, then continue decoding.
        first, cache = jax.jit(
            lambda p, t: model.prefill(POLICY, p, cache_len, tokens=t,
                                       **extra))(params, tokens[:, :1])
        logits = first
        step = jax.jit(lambda p, tok, c, pos: model.decode_step(
            POLICY, p, tok, c, pos))
        for i in range(1, s):
            logits, cache = step(params, tokens[:, i:i + 1], cache,
                                 jnp.full((b,), i, jnp.int32))
    else:
        cache = model.init_cache(b, cache_len)
        step = jax.jit(lambda p, tok, c, pos: model.decode_step(
            POLICY, p, tok, c, pos))
        logits = None
        for i in range(s):
            logits, cache = step(params, tokens[:, i:i + 1], cache,
                                 jnp.full((b,), i, jnp.int32))

    # bf16 accumulation differs between one-shot prefill and step-by-step
    # decode; bound the drift loosely, pin greedy tokens exactly.
    np.testing.assert_allclose(
        np.asarray(logits), np.asarray(logits_pre), rtol=0.1, atol=0.25)
    # greedy tokens must agree exactly
    np.testing.assert_array_equal(
        np.asarray(jnp.argmax(logits, -1)),
        np.asarray(jnp.argmax(logits_pre, -1)))
