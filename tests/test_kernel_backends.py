"""Kernel backend registry: Pallas-vs-XLA parity, registry-driven.

Three layers, mirroring the registry's contract (DESIGN.md §8):

  * primitive level — for *every* registered primitive, the Pallas body
    (interpret mode on this CPU container) is bit-exact with the XLA
    reference body on random inputs (a coverage guard fails the suite if a
    primitive is registered without a parity case here);
  * engine level — every registered engine scores identically under
    ``cfg.backend='xla'`` and ``'pallas_interpret'``, and the jit-native
    ``train_step`` is bit-exact across backends in both learning modes;
  * sharded level (subprocess, forced 4-device host platform) — the
    clause-sharded ``scores`` and ``train_step`` run the Pallas route
    (``pallas_call`` present in the lowered jaxpr) with the single (B, m)
    vote all-reduce still the only scores collective, bit-exact with the
    single-device XLA path in both learning modes.
"""
import dataclasses
import subprocess
import sys
import textwrap
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    TMConfig, TMState, bundle_scores, init_bundle, registered_engines,
    train_step)
from repro.core.bitpack import pack_bits, packed_literals
from repro.kernels import backend as kbackend

SRC = str(Path(__file__).resolve().parents[1] / "src")

CFG = TMConfig(n_classes=3, n_clauses=16, n_features=12, n_states=50,
               s=3.0, threshold=4)
ALL_EVENTS = CFG.n_classes * CFG.n_clauses * CFG.n_literals


def random_state(cfg, seed=0, density=0.4):
    rng = np.random.default_rng(seed)
    inc = rng.uniform(
        size=(cfg.n_classes, cfg.n_clauses, cfg.n_literals)) < density
    ta = np.where(inc, cfg.n_states + 1, cfg.n_states)
    return TMState(ta_state=jnp.asarray(ta, jnp.int16))


# ---------------------------------------------------------------------------
# Resolution rules
# ---------------------------------------------------------------------------


def test_resolve_backend_auto_is_xla_off_tpu(monkeypatch):
    monkeypatch.delenv("REPRO_TM_BACKEND", raising=False)
    assert jax.default_backend() != "tpu"  # this container
    assert kbackend.resolve_backend("auto") == "xla"
    assert kbackend.pallas_mode() == "pallas_interpret"


def test_resolve_backend_env_override(monkeypatch):
    monkeypatch.setenv("REPRO_TM_BACKEND", "pallas_interpret")
    assert kbackend.resolve_backend("auto") == "pallas_interpret"
    # explicit backends ignore the env hook
    assert kbackend.resolve_backend("xla") == "xla"
    monkeypatch.setenv("REPRO_TM_BACKEND", "auto")
    with pytest.raises(ValueError):
        kbackend.resolve_backend("auto")


def test_resolve_backend_rejects_unknown():
    with pytest.raises(ValueError):
        kbackend.resolve_backend("cuda")
    with pytest.raises(KeyError):
        kbackend.get_primitive("nope")
    with pytest.raises(ValueError):
        TMConfig(n_classes=2, n_clauses=4, n_features=3, backend="nope")


def test_clause_axis_matches_engines():
    from repro.core.engines import CLAUSE_AXIS
    assert kbackend.CLAUSE_AXIS == CLAUSE_AXIS
    for name in kbackend.registered_primitives():
        part = kbackend.get_primitive(name).partitioning
        assert part.in_specs and part.out_spec is not None, name


def test_partitioning_contract_matches_sharded_wiring():
    """The registry's declared ClausePartitioning must equal what the
    sharded layer actually wires (core/distributed.py / core/engines.py) —
    a drifted declaration is a lie in the docs, so pin them together."""
    from jax.sharding import PartitionSpec as P
    from repro.core.distributed import STATE_PSPEC
    from repro.core.engines import CLAUSE_AXIS, get_engine

    votes = kbackend.get_primitive("clause_votes").partitioning
    # operands: the bitpack engine's cache spec, replicated literals, the
    # polarity slice make_sharded_scores feeds (P(CLAUSE_AXIS)); result:
    # partial votes completed by the one psum the scores factory emits
    assert votes.in_specs == (get_engine("bitpack").cache_pspec(CFG),
                              P(None, None), P(CLAUSE_AXIS))
    assert votes.out_spec == P(None, None) and votes.vote_reduce

    outputs = kbackend.get_primitive("clause_outputs").partitioning
    assert outputs.in_specs[0] == get_engine("bitpack").cache_pspec(CFG)
    assert outputs.out_spec == P(None, None, CLAUSE_AXIS)
    assert not outputs.vote_reduce

    upd = kbackend.get_primitive("ta_update").partitioning
    # a TA class row (n, 2o) is one class slice of STATE_PSPEC (m, n, 2o)
    assert STATE_PSPEC.ta_state == P(None, CLAUSE_AXIS, None)
    assert upd.in_specs[0] == upd.out_spec == P(CLAUSE_AXIS, None)
    assert not upd.vote_reduce  # feedback is clause-local: no collective

    idx_pspec = get_engine("indexed").cache_pspec(CFG)
    iv = kbackend.get_primitive("indexed_votes").partitioning
    # matmul-form Eq. 4 reads the position matrix with the engine's own
    # cache spec; votes are partial sums under the same single psum as
    # clause_votes, padding rows inert through sign-0 polarity
    assert iv.in_specs == (idx_pspec.pos, P(None, None), P(CLAUSE_AXIS))
    assert iv.out_spec == P(None, None) and iv.vote_reduce
    assert iv.clause_padding == "zero_polarity"

    iu = kbackend.get_primitive("index_update").partitioning
    # batched replay: index operands/results carry the engine cache spec
    # verbatim, event columns replicate (each shard diffs its own slice),
    # and no collective fires — maintenance is clause-local
    assert iu.in_specs[:3] == (idx_pspec.lists, idx_pspec.counts,
                               idx_pspec.pos)
    assert iu.in_specs[3:] == (P(None),) * 5
    assert iu.out_spec == (idx_pspec.lists, idx_pspec.counts, idx_pspec.pos)
    assert not iu.vote_reduce
    assert iu.clause_padding == "masked_active"


# ---------------------------------------------------------------------------
# Primitive-level parity: every registered primitive, Pallas == XLA
# ---------------------------------------------------------------------------


def _primitive_case(name, seed):
    """Random (args, kwargs) for one primitive; extend for new primitives."""
    rng = np.random.default_rng(seed)
    m, n, o, b = 3, 18, 13, 5
    include = rng.uniform(size=(m, n, 2 * o)) < 0.35
    x = jnp.asarray(rng.integers(0, 2, (b, o)), jnp.uint8)
    inc_packed = pack_bits(jnp.asarray(include, jnp.uint8))
    lit_packed = packed_literals(x)
    if name == "clause_votes":
        pol = jnp.asarray(rng.choice([-1, 1], n), jnp.int32)
        return (inc_packed, lit_packed, pol), {}
    if name == "clause_outputs":
        return (inc_packed, lit_packed), {}
    if name == "ta_update":
        L = 2 * o
        return (
            jnp.asarray(rng.integers(1, 101, (n, L)), jnp.int16),
            jnp.asarray(rng.integers(0, 2, L), jnp.uint8),
            jnp.asarray(rng.integers(0, 2, n), jnp.uint8),
            jnp.asarray(rng.integers(0, 2, n), bool),
            jnp.asarray(rng.integers(0, 2, n), bool),
            jnp.asarray(rng.uniform(size=(n, L)), jnp.float32),
        ), {"n_states": 50, "s": 3.7, "boost_true_positive": bool(seed % 2)}
    if name == "indexed_votes":
        from repro.core.types import literals_from_input
        # votes read membership (pos != NA) only — slot values are free
        pos = jnp.where(jnp.asarray(include), 7, -1).astype(jnp.int32)
        pol = jnp.asarray(rng.choice([-1, 1], n), jnp.int32)
        return (pos, literals_from_input(x), pol), {}
    if name == "index_update":
        from repro.core import indexing
        from repro.core.types import include_mask
        cfg = dataclasses.replace(
            CFG, n_clauses=n, n_features=o, index_capacity=n)
        ta = np.where(include, cfg.n_states + 1, cfg.n_states)
        state = TMState(ta_state=jnp.asarray(ta, jnp.int16))
        idx = indexing.build_index(cfg, state, n)
        inc = np.asarray(include_mask(cfg, state))
        # 12 distinct boundary crossings (direction from the current mask:
        # insert where excluded, delete where included) + an invalid tail
        cells = rng.choice(m * n * 2 * o, size=12, replace=False)
        ci, rem = np.divmod(cells, n * 2 * o)
        cj, ck = np.divmod(rem, 2 * o)
        valid = np.ones(12, bool)
        valid[-3:] = False
        return (idx.lists, idx.counts, idx.pos,
                jnp.asarray(ci, jnp.int32), jnp.asarray(cj, jnp.int32),
                jnp.asarray(ck, jnp.int32),
                jnp.asarray(~inc[ci, cj, ck]), jnp.asarray(valid)), {}
    raise NotImplementedError(
        f"primitive {name!r} registered without a parity case — add one")


@pytest.mark.parametrize("name", kbackend.registered_primitives())
@pytest.mark.parametrize("seed", range(3))
def test_primitive_pallas_matches_xla(name, seed):
    args, kwargs = _primitive_case(name, seed)
    want = kbackend.resolve(name, "xla")(*args, **kwargs)
    got = kbackend.resolve(name, "pallas_interpret")(*args, **kwargs)
    want_leaves = jax.tree_util.tree_leaves(want)
    got_leaves = jax.tree_util.tree_leaves(got)
    assert len(got_leaves) == len(want_leaves)  # e.g. index_update's 3-tuple
    for g, w in zip(got_leaves, want_leaves):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(w))


def test_every_primitive_has_a_case():
    for name in kbackend.registered_primitives():
        _primitive_case(name, 0)  # raises NotImplementedError when missing


# ---------------------------------------------------------------------------
# Engine-level parity: cfg.backend threads through scores and training
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("name", registered_engines())
def test_engine_scores_parity_across_backends(name):
    state = random_state(CFG, 3)
    rng = np.random.default_rng(9)
    xs = jnp.asarray(rng.integers(0, 2, (7, CFG.n_features)), jnp.uint8)
    outs = {}
    for backend in ("xla", "pallas_interpret"):
        cfg = dataclasses.replace(CFG, backend=backend)
        bundle = init_bundle(cfg, state=state, engines=(name,))
        outs[backend] = np.asarray(bundle_scores(bundle, xs, engine=name))
    np.testing.assert_array_equal(outs["pallas_interpret"], outs["xla"],
                                  err_msg=name)


def test_bitpack_xla_alias_shares_cache_and_pins_backend():
    from repro.core.engines import get_engine
    a, b = get_engine("bitpack"), get_engine("bitpack_xla")
    assert a.cache_key == b.cache_key == "bitpack"
    assert b.backend == "xla" and a.backend is None
    # the alias ignores a pallas cfg: same class, pinned resolution
    cfg = dataclasses.replace(CFG, backend="pallas_interpret")
    assert b._votes(cfg) is kbackend.resolve("clause_votes", "xla")


@pytest.mark.parametrize("parallel", [False, True])
def test_train_step_parity_across_backends(parallel):
    """The fused Pallas training round (clause outputs → ta_update kernel)
    is bit-exact with the XLA bodies, engine caches included."""
    rng = np.random.default_rng(0)
    bundles = {}
    for backend in ("xla", "pallas_interpret"):
        cfg = dataclasses.replace(CFG, backend=backend)
        bundle = init_bundle(cfg, state=random_state(cfg, 1))
        key = jax.random.key(2)
        data = np.random.default_rng(7)
        for _ in range(3):
            key, sub = jax.random.split(key)
            xs = jnp.asarray(data.integers(0, 2, (6, cfg.n_features)),
                             jnp.uint8)
            ys = jnp.asarray(data.integers(0, cfg.n_classes, 6), jnp.int32)
            bundle = train_step(bundle, xs, ys, sub, parallel=parallel,
                                max_events=ALL_EVENTS)
        bundles[backend] = bundle
    ref = bundles["xla"]
    got = bundles["pallas_interpret"]
    np.testing.assert_array_equal(np.asarray(got.state.ta_state),
                                  np.asarray(ref.state.ta_state))
    assert int(got.event_overflow) == 0
    xs = jnp.asarray(rng.integers(0, 2, (5, CFG.n_features)), jnp.uint8)
    want = np.asarray(bundle_scores(ref, xs, engine="dense"))
    for name in registered_engines():
        np.testing.assert_array_equal(
            np.asarray(bundle_scores(got, xs, engine=name)), want,
            err_msg=name)


# ---------------------------------------------------------------------------
# Sharded: Pallas route under shard_map on a forced 4-device host platform
# ---------------------------------------------------------------------------

SHARDED_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import dataclasses
    import jax, jax.numpy as jnp
    import numpy as np

    from repro.core import (
        TMConfig, TMSession, TMState, Topology, bundle_scores, init_bundle,
        registered_engines, train_step)
    from repro.launch import hlo as hlo_mod

    cfg = TMConfig(n_classes=3, n_clauses=16, n_features=12, n_states=50,
                   s=3.0, threshold=4)
    ALL = cfg.n_classes * cfg.n_clauses * cfg.n_literals
    rng = np.random.default_rng(0)
    inc = rng.uniform(size=(3, 16, 24)) < 0.4
    state = TMState(ta_state=jnp.asarray(
        np.where(inc, cfg.n_states + 1, cfg.n_states), jnp.int16))
    xs_eval = jnp.asarray(rng.integers(0, 2, (8, 12)), jnp.uint8)

    ref = init_bundle(dataclasses.replace(cfg, backend="xla"), state=state)
    want = np.asarray(bundle_scores(ref, xs_eval, engine="dense"))

    # Topology(backend=...) overrides the config's choice at resolution
    stm = TMSession(cfg, Topology(clause_shards=4,
                                  backend="pallas_interpret"),
                    max_events=ALL)
    assert stm.cfg.backend == "pallas_interpret"
    assert stm.describe()["backend"] == "pallas_interpret"
    sb = stm.prepare(state)

    # ---- sharded scores: every engine bit-exact; bitpack runs the kernel
    for name in registered_engines():
        got = np.asarray(stm.scores(sb, xs_eval, engine=name))
        np.testing.assert_array_equal(got, want, err_msg=name)
    print("backend-sharded-scores-ok")

    # the bitpack route really is Pallas (kernel call in the jaxpr) and the
    # vote all-reduce is still the one and only collective
    from repro.core.distributed import make_sharded_scores
    from repro.core.engines import get_engine
    eng = get_engine("bitpack")
    s = make_sharded_scores(stm.cfg, stm.mesh, engine="bitpack")
    cache = sb.caches[eng.cache_key]
    jaxpr = str(jax.make_jaxpr(s.jitted)(cache, s.pol, xs_eval))
    assert "pallas_call" in jaxpr, "bitpack did not route through Pallas"
    coll = hlo_mod.collective_stats(
        s.jitted.lower(cache, s.pol, xs_eval).compile().as_text())
    assert coll.count == 1 and set(coll.by_kind) == {"all-reduce"}, (
        coll.count, coll.by_kind)
    # the XLA route on the same mesh has no kernel call
    s_x = make_sharded_scores(dataclasses.replace(stm.cfg, backend="xla"),
                              stm.mesh, engine="bitpack")
    assert "pallas_call" not in str(
        jax.make_jaxpr(s_x.jitted)(cache, s_x.pol, xs_eval))
    print("backend-sharded-route-ok")

    # ---- sharded fused training round: both learning modes, bit-exact
    for parallel in (False, True):
        st_sh = TMSession(cfg, Topology(clause_shards=4,
                                        backend="pallas_interpret"),
                          parallel=parallel, max_events=ALL)
        b_ref = init_bundle(dataclasses.replace(cfg, backend="xla"),
                            state=state)
        b_sh = st_sh.prepare(state)
        key = jax.random.key(1)
        data = np.random.default_rng(5)
        for _ in range(2):
            key, sub = jax.random.split(key)
            bx = jnp.asarray(data.integers(0, 2, (8, 12)), jnp.uint8)
            by = jnp.asarray(data.integers(0, 3, 8), jnp.int32)
            b_ref = train_step(b_ref, bx, by, sub, parallel=parallel,
                               max_events=ALL)
            b_sh = st_sh.train_step(b_sh, bx, by, sub)
        np.testing.assert_array_equal(
            np.asarray(b_sh.state.ta_state), np.asarray(b_ref.state.ta_state),
            err_msg=f"parallel={parallel}")
        assert int(b_sh.event_overflow) == 0
        for name in registered_engines():
            np.testing.assert_array_equal(
                np.asarray(st_sh.scores(b_sh, xs_eval, engine=name)),
                np.asarray(bundle_scores(b_ref, xs_eval, engine="dense")),
                err_msg=f"{name} parallel={parallel}")
    print("backend-sharded-train-ok")
""")


@pytest.mark.slow
def test_kernel_backends_sharded_subprocess():
    res = subprocess.run(
        [sys.executable, "-c", SHARDED_SCRIPT],
        env={"PYTHONPATH": SRC, "PATH": "/usr/bin:/bin", "HOME": "/root"},
        capture_output=True, text=True, timeout=900)
    assert res.returncode == 0, res.stdout + "\n" + res.stderr
    for marker in ("backend-sharded-scores-ok", "backend-sharded-route-ok",
                   "backend-sharded-train-ok"):
        assert marker in res.stdout, res.stdout + "\n" + res.stderr
