"""LR schedules (pure functions of the int step)."""
from __future__ import annotations

import jax.numpy as jnp


def cosine_with_warmup(step, *, peak_lr, warmup_steps, total_steps,
                       min_ratio=0.1):
    step = step.astype(jnp.float32)
    warm = peak_lr * step / jnp.maximum(1.0, warmup_steps)
    t = jnp.clip((step - warmup_steps)
                 / jnp.maximum(1.0, total_steps - warmup_steps), 0.0, 1.0)
    cos = peak_lr * (min_ratio + (1 - min_ratio) * 0.5
                     * (1 + jnp.cos(jnp.pi * t)))
    return jnp.where(step < warmup_steps, warm, cos)


def constant(step, *, peak_lr, **_):
    del step
    return jnp.asarray(peak_lr, jnp.float32)
