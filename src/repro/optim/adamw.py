"""AdamW with fp32 master weights + global-norm clipping (pure JAX).

The optimizer state is a pytree mirroring params (all fp32), sharded with
the same PartitionSpecs — ZeRO-style: each FSDP×TP shard owns its slice of
master weights and moments.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array      # () int32
    mu: object           # pytree fp32
    nu: object           # pytree fp32


def init(params) -> AdamWState:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return AdamWState(
        step=jnp.zeros((), jnp.int32),
        mu=jax.tree.map(zeros, params),
        nu=jax.tree.map(zeros, params),
    )


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(
        jnp.sum(jnp.square(g.astype(jnp.float32)))
        for g in jax.tree.leaves(tree)))


def clip_by_global_norm(grads, max_norm):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: g * scale, grads), norm


def update(
    grads,
    state: AdamWState,
    params,
    *,
    lr: jax.Array | float,
    b1: float = 0.9,
    b2: float = 0.95,
    eps: float = 1e-8,
    weight_decay: float = 0.1,
    max_grad_norm: float | None = 1.0,
):
    """Returns (new_params, new_state, metrics). Params/grads fp32."""
    grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
    if max_grad_norm is not None:
        grads, gnorm = clip_by_global_norm(grads, max_grad_norm)
    else:
        gnorm = global_norm(grads)
    step = state.step + 1
    t = step.astype(jnp.float32)
    bc1 = 1.0 - b1 ** t
    bc2 = 1.0 - b2 ** t

    def upd(p, g, m, v):
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * jnp.square(g)
        mhat = m / bc1
        vhat = v / bc2
        new_p = p - lr * (mhat / (jnp.sqrt(vhat) + eps) + weight_decay * p)
        return new_p, m, v

    out = jax.tree.map(upd, params, grads, state.mu, state.nu)
    new_params = jax.tree.map(lambda o: o[0], out,
                              is_leaf=lambda x: isinstance(x, tuple))
    new_mu = jax.tree.map(lambda o: o[1], out,
                          is_leaf=lambda x: isinstance(x, tuple))
    new_nu = jax.tree.map(lambda o: o[2], out,
                          is_leaf=lambda x: isinstance(x, tuple))
    return new_params, AdamWState(step, new_mu, new_nu), {
        "grad_norm": gnorm, "lr": jnp.asarray(lr, jnp.float32)}
