"""Gradient compression for cross-pod reduction (distributed-opt trick).

At 1000+ node scale the ``pod`` axis all-reduce is the slowest collective
(DCN, not ICI). Two compressors, both with error feedback so the *training
trajectory* converges to the uncompressed one:

  * bf16  — halves cross-pod bytes; error feedback buffers the rounding
            residual (fp32 - bf16) and re-adds it next step.
  * int8  — per-tensor scaled int8 (8×), same error-feedback contract.

Applied at the microbatch-accumulation boundary: local fp32 accumulation,
compress, (implicit GSPMD) all-reduce, decompress, add residual.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class ErrorFeedback(NamedTuple):
    residual: object  # pytree fp32, same structure as grads


def init_error_feedback(params) -> ErrorFeedback:
    return ErrorFeedback(
        residual=jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params))


def _compress_bf16(g):
    c = g.astype(jnp.bfloat16)
    return c, g - c.astype(jnp.float32)


def _compress_int8(g):
    scale = jnp.maximum(jnp.max(jnp.abs(g)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    deq = q.astype(jnp.float32) * scale
    return deq, g - deq


def compress_grads(grads, ef: ErrorFeedback, *, mode: str = "bf16"):
    """Returns (compressed grads ready for reduction, new error feedback).

    mode: "none" | "bf16" | "int8".
    """
    if mode == "none":
        return grads, ef
    fn = {"bf16": _compress_bf16, "int8": _compress_int8}[mode]

    def one(g, r):
        c, new_r = fn(g.astype(jnp.float32) + r)
        return c, new_r

    out = jax.tree.map(one, grads, ef.residual)
    comp = jax.tree.map(lambda o: o[0], out,
                        is_leaf=lambda x: isinstance(x, tuple))
    res = jax.tree.map(lambda o: o[1], out,
                       is_leaf=lambda x: isinstance(x, tuple))
    return comp, ErrorFeedback(residual=res)
