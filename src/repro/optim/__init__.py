"""Optimizer substrate: AdamW (fp32 masters), schedules, grad compression."""
