"""Synthetic data generators (offline container — no dataset downloads).

TM side — distribution-matched stand-ins for the paper's three datasets
(§4): binarized images (MNIST/F-MNIST-like: o ∈ {784, 1568, 2352, 3136},
~20-40% active bits, class-dependent templates) and bag-of-words sets
(IMDb-like: o ∈ {5000..20000}, ~0.5-2% active — the sparsity regime that
drives the paper's 0.006 work ratio).

LM side — token streams with Zipfian unigram statistics + a repeated-ngram
structure so cross-entropy actually decreases during the example runs.
"""
from __future__ import annotations

import numpy as np


def templated_images(templates, n, *, noise=0.05, rng):
    """Draw n noisy samples from fixed class templates → (x uint8, y int32).

    The single source of the template⊕flip scheme: ``binarized_images``
    (one-shot datasets) and ``data/pipeline.TMBatcher`` (step-indexed
    training/serving streams) both sample through here, so the training and
    serving distributions cannot silently diverge.
    """
    n_classes, o = templates.shape
    y = rng.integers(0, n_classes, n).astype(np.int32)
    flip = rng.uniform(size=(n, o)) < noise
    x = templates[y] ^ flip
    return x.astype(np.uint8), y


def binarized_images(n, o, n_classes=10, *, active=0.3, noise=0.05, seed=0):
    """Class-template Bernoulli images → (x (n, o) uint8, y (n,) int32)."""
    rng = np.random.default_rng(seed)
    templates = rng.uniform(size=(n_classes, o)) < active
    return templated_images(templates, n, noise=noise, rng=rng)


def bow_documents(n, o, n_classes=2, *, active_frac=0.01, signal=40, seed=0):
    """IMDb-like sparse bag-of-words: (x (n, o) uint8, y (n,))."""
    rng = np.random.default_rng(seed)
    n_active = max(4, int(active_frac * o))
    y = rng.integers(0, n_classes, n).astype(np.int32)
    # class-specific signal vocab + shared background
    sig = rng.integers(0, o, (n_classes, signal))
    x = np.zeros((n, o), np.uint8)
    for i in range(n):
        bg = rng.integers(0, o, n_active)
        x[i, bg] = 1
        take = rng.integers(0, signal, max(2, signal // 4))
        x[i, sig[y[i], take]] = 1
    return x, y


def token_stream(n_tokens, vocab, *, seed=0, ngram=8, n_patterns=512):
    """Zipfian tokens with injected repeated n-grams (learnable signal)."""
    rng = np.random.default_rng(seed)
    ranks = np.arange(1, vocab + 1, dtype=np.float64)
    probs = 1.0 / ranks
    probs /= probs.sum()
    toks = rng.choice(vocab, size=n_tokens, p=probs).astype(np.int32)
    patterns = rng.choice(vocab, size=(n_patterns, ngram), p=probs)
    n_inject = n_tokens // (ngram * 4)
    pos = rng.integers(0, max(1, n_tokens - ngram), n_inject)
    pat = rng.integers(0, n_patterns, n_inject)
    for p, q in zip(pos, pat):
        toks[p:p + ngram] = patterns[q]
    return toks
