"""data substrate."""
