"""Sharded host data pipeline with background prefetch.

Each process feeds only its addressable batch shard (``process_index``-keyed
slicing — identical maths on a real multi-host pod), with a double-buffered
prefetch thread so host data prep overlaps device steps. Determinism: the
stream is a pure function of (seed, step), so restarts resume the exact
batch sequence from the checkpointed step — a fault-tolerance requirement,
not a nicety.
"""
from __future__ import annotations

import queue
import threading
from typing import Callable, Iterator

import jax
import numpy as np

from repro.data.synthetic import token_stream


class TokenBatcher:
    """Deterministic (seed, step) → batch of (tokens, labels)."""

    def __init__(self, vocab: int, batch: int, seq: int, *, seed: int = 0,
                 shard_index: int = 0, shard_count: int = 1):
        self.vocab, self.batch, self.seq = vocab, batch, seq
        self.seed = seed
        self.shard_index, self.shard_count = shard_index, shard_count
        assert batch % shard_count == 0
        self.local_batch = batch // shard_count

    def __call__(self, step: int) -> dict:
        n = self.local_batch * (self.seq + 1)
        # fold (seed, step, shard) into the stream offset — deterministic
        toks = token_stream(
            n, self.vocab,
            seed=(self.seed * 1_000_003 + step * 613 + self.shard_index))
        toks = toks.reshape(self.local_batch, self.seq + 1)
        return {"tokens": toks[:, :-1].astype(np.int32),
                "labels": toks[:, 1:].astype(np.int32)}


class Prefetcher:
    """Double-buffered background prefetch of a step-indexed source."""

    def __init__(self, source: Callable[[int], dict], start_step: int = 0,
                 depth: int = 2):
        self.source = source
        self.q: queue.Queue = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self._step = start_step

        def work():
            s = start_step
            while not self._stop.is_set():
                try:
                    self.q.put((s, self.source(s)), timeout=0.2)
                    s += 1
                except queue.Full:
                    continue

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def __iter__(self) -> Iterator[tuple[int, dict]]:
        while True:
            yield self.q.get()

    def close(self):
        self._stop.set()
        self._thread.join(timeout=2)
