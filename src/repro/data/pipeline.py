"""Sharded host data pipeline with background prefetch.

Each process feeds only its addressable batch shard (``process_index``-keyed
slicing — identical maths on a real multi-host pod), with a double-buffered
prefetch thread so host data prep overlaps device steps. Determinism: the
stream is a pure function of (seed, step), so restarts resume the exact
batch sequence from the checkpointed step — a fault-tolerance requirement,
not a nicety.
"""
from __future__ import annotations

import queue
import threading
from typing import Callable, Iterator

import jax
import numpy as np

from repro.data.synthetic import templated_images, token_stream


class TokenBatcher:
    """Deterministic (seed, step) → batch of (tokens, labels)."""

    def __init__(self, vocab: int, batch: int, seq: int, *, seed: int = 0,
                 shard_index: int = 0, shard_count: int = 1):
        self.vocab, self.batch, self.seq = vocab, batch, seq
        self.seed = seed
        self.shard_index, self.shard_count = shard_index, shard_count
        assert batch % shard_count == 0
        self.local_batch = batch // shard_count

    def __call__(self, step: int) -> dict:
        n = self.local_batch * (self.seq + 1)
        # fold (seed, step, shard) into the stream offset — deterministic
        toks = token_stream(
            n, self.vocab,
            seed=(self.seed * 1_000_003 + step * 613 + self.shard_index))
        toks = toks.reshape(self.local_batch, self.seq + 1)
        return {"tokens": toks[:, :-1].astype(np.int32),
                "labels": toks[:, 1:].astype(np.int32)}


class TMBatcher:
    """Deterministic (seed, step) → TM batch {"x": (B, o) uint8, "y": (B,)}.

    Class-template Bernoulli images (cf. data/synthetic.py) with the
    templates fixed by ``seed`` and the per-step noise a pure function of
    (seed, step) — restarting from a checkpointed step replays the exact
    batch sequence, the TM fault-tolerance requirement. ``shard_index`` /
    ``shard_count`` take contiguous row blocks of the *global* batch, so
    data shards compose back to the single-process stream (bit-exact
    sharded-vs-single parity in tests/test_tm_sharded.py relies on this).
    """

    def __init__(self, n_features: int, n_classes: int, batch: int, *,
                 seed: int = 0, active: float = 0.3, noise: float = 0.05,
                 shard_index: int = 0, shard_count: int = 1):
        self.n_features, self.n_classes = n_features, n_classes
        self.batch, self.seed = batch, seed
        self.active, self.noise = active, noise
        self.shard_index, self.shard_count = shard_index, shard_count
        assert batch % shard_count == 0
        self.local_batch = batch // shard_count
        rng = np.random.default_rng(seed)
        self._templates = rng.uniform(size=(n_classes, n_features)) < active

    def __call__(self, step: int) -> dict:
        rng = np.random.default_rng(self.seed * 1_000_003 + 7919 * step + 1)
        x, y = templated_images(self._templates, self.batch,
                                noise=self.noise, rng=rng)
        lo = self.shard_index * self.local_batch
        hi = lo + self.local_batch
        return {"x": x[lo:hi], "y": y[lo:hi]}


class Prefetcher:
    """Double-buffered background prefetch of a step-indexed source."""

    def __init__(self, source: Callable[[int], dict], start_step: int = 0,
                 depth: int = 2):
        self.source = source
        self.q: queue.Queue = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self._step = start_step

        def work():
            s = start_step
            while not self._stop.is_set():
                try:
                    self.q.put((s, self.source(s)), timeout=0.2)
                    s += 1
                except queue.Full:
                    continue

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def __iter__(self) -> Iterator[tuple[int, dict]]:
        while True:
            yield self.q.get()

    def close(self):
        self._stop.set()
        self._thread.join(timeout=2)
