"""llava-next-mistral-7b [vlm]: Mistral-7B backbone + anyres vision stub.

[hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified] — 32L, d_model 4096,
32 heads (GQA kv=8), d_ff 14336, vocab 32000. The anyres tiling frontend is
a stub per the assignment: input_specs provides pre-projected patch
embeddings (2880 = 576 base + 4×576 tiles).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llava-next-mistral-7b", family="vlm",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8, d_ff=14336,
    vocab=32000, head_dim=128, rope_theta=1e6, n_vision_tokens=2880,
)
