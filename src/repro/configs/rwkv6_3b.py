"""rwkv6-3b [ssm]: Finch — data-dependent decay, attention-free
(arXiv:2404.05892). 32L, d_model 2560, d_ff 8960, vocab 65536,
head_size 64 (40 wkv heads). O(1)-per-token state ⇒ long_500k eligible.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-3b", family="ssm",
    n_layers=32, d_model=2560, n_heads=40, n_kv_heads=40, d_ff=8960,
    vocab=65536, rwkv_head_dim=64, rwkv_chunk=32, norm="layernorm",
)
