"""whisper-medium [audio]: enc-dec, conv frontend stubbed (arXiv:2212.04356).

24 encoder + 24 decoder layers, d_model 1024, 16 heads (kv=16), d_ff 4096,
vocab 51865, 1500 encoder frames, LayerNorm + GELU, tied unembedding.
Prefill/decode shape cells exercise the decoder (DESIGN.md §5).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-medium", family="encdec",
    n_layers=24, n_enc_layers=24, d_model=1024, n_heads=16, n_kv_heads=16,
    d_ff=4096, vocab=51865, head_dim=64, norm="layernorm", act="gelu",
    tie_embeddings=True, enc_seq=1500,
)
