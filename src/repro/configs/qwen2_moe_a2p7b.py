"""qwen2-moe-a2.7b [moe]: 4 shared + 60 routed top-4
(hf:Qwen/Qwen1.5-MoE-A2.7B). 24L, d_model 2048, 16 heads (kv=16),
expert d_ff 1408 (shared 5632), vocab 151936, QKV bias.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-moe-a2.7b", family="moe",
    n_layers=24, d_model=2048, n_heads=16, n_kv_heads=16, d_ff=1408,
    d_ff_expert=1408, d_ff_shared=5632, vocab=151936, head_dim=128,
    qkv_bias=True, rope_theta=1e6, n_experts=60, top_k=4,
    n_shared_experts=4, normalize_topk=False,
    sp_residual=False,  # §Perf hillclimb B: SP↔group all-to-alls cost more than SP saves for MoE
)
