"""The paper's own experiment configs (§4): MNIST / F-MNIST / IMDb grids.

M1–M4, F1–F4: binarized images at 1–4 threshold bits (o = 784·bits);
I1–I4: bag-of-words at o ∈ {5k, 10k, 15k, 20k}. Clause counts sweep
{1000, 2000, 5000, 10000, 20000} in the paper; benchmark defaults are
scaled down for the 1-core container but keep the grid structure.
"""
from __future__ import annotations

import dataclasses

from repro.core.types import TMConfig


@dataclasses.dataclass(frozen=True)
class TMExperiment:
    name: str
    tm: TMConfig
    dataset: str          # "image" | "bow"
    # sparsity stats used by synthetic data + the work-ratio analysis
    avg_clause_len: float # paper §3: MNIST ≈ 58, IMDb ≈ 116


def mnist_like(bits: int = 1, n_clauses: int = 2000) -> TMExperiment:
    o = 784 * bits
    return TMExperiment(
        name=f"M{bits}",
        tm=TMConfig(n_classes=10, n_clauses=n_clauses, n_features=o,
                    n_states=127, s=10.0, threshold=50),
        dataset="image", avg_clause_len=58.0)


def fmnist_like(bits: int = 1, n_clauses: int = 2000) -> TMExperiment:
    return dataclasses.replace(mnist_like(bits, n_clauses),
                               name=f"F{bits}")


def imdb_like(o: int = 5000, n_clauses: int = 2000) -> TMExperiment:
    return TMExperiment(
        name=f"I{o//5000}",
        tm=TMConfig(n_classes=2, n_clauses=n_clauses, n_features=o,
                    n_states=127, s=27.0, threshold=40),
        dataset="bow", avg_clause_len=116.0)


PAPER_TM_CONFIGS = {
    "tm_mnist": mnist_like(1),
    "tm_fashion_mnist": fmnist_like(1),
    "tm_imdb": imdb_like(5000),
}
