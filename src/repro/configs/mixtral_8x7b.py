"""mixtral-8x7b [moe]: 8 experts top-2 + SWA (arXiv:2401.04088).

32L, d_model 4096, 32 heads (GQA kv=8), expert d_ff 14336, vocab 32000,
sliding window 4096 ⇒ rolling-buffer decode cache ⇒ long_500k eligible.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mixtral-8x7b", family="moe",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8, d_ff=14336,
    d_ff_expert=14336, vocab=32000, head_dim=128, rope_theta=1e6,
    n_experts=8, top_k=2, sliding_window=4096,
    sp_residual=False,  # §Perf hillclimb B: SP↔group all-to-alls cost more than SP saves for MoE
)
