"""recurrentgemma-9b [hybrid]: RG-LRU + local attention 1:2
(arXiv:2402.19427). 38L, d_model 4096, 16 heads (MQA kv=1), d_ff 12288,
vocab 256000, local window 2048, pattern (rec, rec, attn) — 12 groups + 2
trailing recurrent blocks. Windowed cache + O(d_rnn) state ⇒ long_500k.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-9b", family="hybrid",
    n_layers=38, d_model=4096, n_heads=16, n_kv_heads=1, d_ff=12288,
    vocab=256000, head_dim=256, d_rnn=4096, local_window=2048,
    pattern=("rec", "rec", "attn"), rnn_chunk=256, tie_embeddings=True,
)
