"""Model/shape configuration schema + registry helpers."""
from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    """One assigned (input-shape) cell."""

    name: str            # train_4k | prefill_32k | decode_32k | long_500k
    kind: str            # train | prefill | decode
    seq_len: int
    global_batch: int
    # decode shapes: cache length == seq_len (window-limited where noted)

    @property
    def tokens(self) -> int:
        return self.seq_len * self.global_batch


TRAIN_4K = ShapeSpec("train_4k", "train", 4096, 256)
PREFILL_32K = ShapeSpec("prefill_32k", "prefill", 32768, 32)
DECODE_32K = ShapeSpec("decode_32k", "decode", 32768, 128)
LONG_500K = ShapeSpec("long_500k", "decode", 524288, 1)

LM_SHAPES = (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: Optional[int] = None   # default d_model // n_heads
    qk_norm: bool = False
    qkv_bias: bool = False
    rope_theta: float = 1e4
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    act: str = "silu"
    norm: str = "rmsnorm"            # rmsnorm | layernorm
    sliding_window: Optional[int] = None
    # --- moe ---
    n_experts: int = 0
    top_k: int = 0
    n_shared_experts: int = 0
    d_ff_expert: Optional[int] = None
    d_ff_shared: Optional[int] = None
    capacity_factor: float = 1.25
    moe_dispatch: str = "sort"       # sort | einsum (GShard baseline)
    normalize_topk: bool = True
    # --- ssm (rwkv6) ---
    rwkv_head_dim: int = 64
    rwkv_chunk: int = 32
    # --- hybrid (griffin) ---
    d_rnn: Optional[int] = None
    local_window: Optional[int] = None
    pattern: tuple = ()              # e.g. ("rec", "rec", "attn")
    rnn_chunk: int = 256
    # --- encdec (whisper) ---
    n_enc_layers: int = 0
    enc_seq: int = 0                 # stub frame count (whisper: 1500)
    # --- vlm ---
    n_vision_tokens: int = 0
    # --- numerics / exec ---
    remat: bool = True
    dense_attn_max: int = 8192       # above → blockwise flash-scan attention
    kv_block: int = 512
    # Megatron-SP residual sharding (seq on model between blocks). Worth
    # it for long-seq dense stacks; for MoE the grouped-dispatch layout
    # transition costs an all-to-all per block (§Perf hillclimb B).
    sp_residual: bool = True
    # use_scan=False unrolls all layer/microbatch loops — used by the
    # roofline probe compiles so cost_analysis counts every op exactly
    # (XLA's cost model counts while-loop bodies once; DESIGN.md §6).
    use_scan: bool = True
    # reduced smoke-config factory is per-arch (configs/<id>.py)

    @property
    def head_dim_(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def rwkv_heads(self) -> int:
        return self.d_model // self.rwkv_head_dim

    def param_count(self) -> int:
        """Analytic parameter count (embedding included once if tied)."""
        d, v = self.d_model, self.vocab
        emb = v * d * (1 if self.tie_embeddings else 2)
        if self.family == "ssm":  # rwkv6
            tm = 4 * d * d + d * d  # r,k,v,g,o
            tm += d * 5 * 32 + 5 * 32 * d + d * 64 + 64 * d  # loras
            cm = 2 * d * self.d_ff + d * d
            return emb + self.n_layers * (tm + cm)
        hd = self.head_dim_
        attn = d * (self.n_heads * hd) * 2 + d * (self.n_kv_heads * hd) * 2
        if self.family == "moe":
            ffe = self.d_ff_expert or self.d_ff
            moe = self.n_experts * 3 * d * ffe + d * self.n_experts
            if self.n_shared_experts:
                moe += 3 * d * (self.d_ff_shared or self.n_shared_experts * ffe)
            block = attn + moe
            return emb + self.n_layers * block
        if self.family == "hybrid":
            dr = self.d_rnn or d
            rec = 2 * d * dr + 2 * dr * dr + dr * d
            mlp = 3 * d * self.d_ff
            n_attn = self.n_layers // 3
            n_rec = self.n_layers - n_attn
            return emb + n_rec * (rec + mlp) + n_attn * (attn + mlp)
        mlp = (3 if self.act == "silu" else 2) * d * self.d_ff
        layers = self.n_layers + self.n_enc_layers
        return emb + layers * (attn + mlp)

    def active_param_count(self) -> int:
        """Params touched per token (MoE: routed top-k + shared only)."""
        if self.family != "moe":
            return self.param_count()
        d = self.d_model
        ffe = self.d_ff_expert or self.d_ff
        hd = self.head_dim_
        attn = d * (self.n_heads * hd) * 2 + d * (self.n_kv_heads * hd) * 2
        act = self.top_k * 3 * d * ffe + d * self.n_experts
        if self.n_shared_experts:
            act += 3 * d * (self.d_ff_shared or self.n_shared_experts * ffe)
        emb = self.vocab * d * (1 if self.tie_embeddings else 2)
        return emb + self.n_layers * (attn + act)

    def supports_long_context(self) -> bool:
        """Sub-quadratic serving memory (long_500k eligibility)."""
        return self.family in ("ssm", "hybrid") or self.sliding_window is not None

    def has_decoder(self) -> bool:
        return True  # all assigned archs have decode paths (whisper enc-dec)
