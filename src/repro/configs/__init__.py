"""Config registry: assigned architectures + the paper's own TM configs."""
from __future__ import annotations

import dataclasses
import importlib

from repro.configs.base import (
    DECODE_32K,
    LM_SHAPES,
    LONG_500K,
    PREFILL_32K,
    TRAIN_4K,
    ModelConfig,
    ShapeSpec,
)

_ARCH_MODULES = {
    "llava-next-mistral-7b": "llava_next_mistral_7b",
    "whisper-medium": "whisper_medium",
    "qwen3-1.7b": "qwen3_1p7b",
    "granite-8b": "granite_8b",
    "qwen2-72b": "qwen2_72b",
    "minitron-4b": "minitron_4b",
    "rwkv6-3b": "rwkv6_3b",
    "recurrentgemma-9b": "recurrentgemma_9b",
    "mixtral-8x7b": "mixtral_8x7b",
    "qwen2-moe-a2.7b": "qwen2_moe_a2p7b",
}

ARCHS = tuple(_ARCH_MODULES)


def get_config(name: str) -> ModelConfig:
    if name not in _ARCH_MODULES:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_ARCH_MODULES)}")
    mod = importlib.import_module(f"repro.configs.{_ARCH_MODULES[name]}")
    return mod.CONFIG


def get_shape(name: str) -> ShapeSpec:
    shapes = {s.name: s for s in LM_SHAPES}
    return shapes[name]


def reduce_config(cfg: ModelConfig) -> ModelConfig:
    """Reduced same-family config for CPU smoke tests."""
    upd: dict = dict(
        d_model=64, n_heads=4, n_kv_heads=min(cfg.n_kv_heads, 2),
        head_dim=16, d_ff=128, vocab=128, remat=False, dense_attn_max=8192,
        kv_block=16,
    )
    if cfg.family == "encdec":
        upd.update(n_layers=2, n_enc_layers=2, enc_seq=8)
    elif cfg.family == "hybrid":
        upd.update(n_layers=5, d_rnn=64, local_window=8, rnn_chunk=4,
                   head_dim=16, n_kv_heads=1)
    elif cfg.family == "ssm":
        upd.update(n_layers=2, rwkv_head_dim=16, rwkv_chunk=4,
                   n_heads=4, n_kv_heads=4)
    elif cfg.family == "moe":
        upd.update(n_layers=2, n_experts=4, top_k=2,
                   d_ff_expert=32,
                   d_ff_shared=64 if cfg.n_shared_experts else None,
                   n_shared_experts=min(cfg.n_shared_experts, 2))
    elif cfg.family == "vlm":
        upd.update(n_layers=2, n_vision_tokens=4)
    else:
        upd.update(n_layers=2)
    if cfg.sliding_window:
        upd["sliding_window"] = 8
    return dataclasses.replace(cfg, **upd)


def shapes_for(cfg: ModelConfig) -> tuple[ShapeSpec, ...]:
    """The shape cells this arch runs (long_500k gated per DESIGN.md §5)."""
    out = [TRAIN_4K, PREFILL_32K, DECODE_32K]
    if cfg.supports_long_context():
        out.append(LONG_500K)
    return tuple(out)


SKIPPED_CELLS: dict[tuple[str, str], str] = {
    (a, "long_500k"): "skip:full-attn (quadratic KV at 500k; DESIGN.md §5)"
    for a in ARCHS
    if not get_config(a).supports_long_context()
}
