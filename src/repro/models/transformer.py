"""Decoder-only LM assembly: dense / moe / vlm / ssm / hybrid families.

Layer stacks are ``lax.scan``s over stacked params (MaxText-style): the HLO
contains ONE layer body per distinct block kind regardless of depth — this
keeps 80-layer dry-run compiles tractable and is also how the roofline
harness recovers per-layer costs (DESIGN.md §6).

API (all pure functions; ``policy`` carries sharding constraints):
  init_params(rng, cfg)                     → params pytree
  apply_train(cfg, policy, params, batch)   → (logits, aux)
  prefill(cfg, policy, params, tokens, cache_len, …) → (logits_last, cache)
  decode_step(cfg, policy, params, token, cache, pos) → (logits, cache)
  init_cache(cfg, batch, cache_len)         → cache pytree
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as attn_mod
from repro.models import griffin as griffin_mod
from repro.models import rwkv6 as rwkv_mod
from repro.models.common import (
    dense_init,
    embed,
    init_embed,
    init_layernorm,
    init_rmsnorm,
    layernorm,
    rmsnorm,
    unembed,
)
from repro.models.mlp import init_mlp, mlp
from repro.models.moe import init_moe, moe_block
from repro.sharding import Policy

COMPUTE_DTYPE = jnp.bfloat16


def _norm_fns(cfg):
    if cfg.norm == "layernorm":
        return init_layernorm, functools.partial(layernorm, eps=cfg.norm_eps)
    return init_rmsnorm, functools.partial(rmsnorm, eps=cfg.norm_eps)


# ---------------------------------------------------------------------------
# Block init/apply by kind
# ---------------------------------------------------------------------------


def _init_attn_block(rng, cfg: ModelConfig, *, mixer: str):
    """mixer: 'mlp' or 'moe'."""
    init_norm, _ = _norm_fns(cfg)
    k1, k2 = jax.random.split(rng)
    p = {
        "norm1": init_norm(cfg.d_model),
        "norm2": init_norm(cfg.d_model),
        "attn": attn_mod.init_attention(
            k1, cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim_,
            qkv_bias=cfg.qkv_bias, qk_norm=cfg.qk_norm),
    }
    if mixer == "moe":
        p["moe"] = init_moe(
            k2, cfg.d_model, cfg.d_ff_expert or cfg.d_ff, cfg.n_experts,
            n_shared=cfg.n_shared_experts, d_ff_shared=cfg.d_ff_shared)
    else:
        p["mlp"] = init_mlp(k2, cfg.d_model, cfg.d_ff,
                            gated=(cfg.act == "silu"))
    return p


def _init_rec_block(rng, cfg: ModelConfig):
    init_norm, _ = _norm_fns(cfg)
    k1, k2 = jax.random.split(rng)
    return {
        "norm1": init_norm(cfg.d_model),
        "norm2": init_norm(cfg.d_model),
        "rec": griffin_mod.init_recurrent_block(
            k1, cfg.d_model, cfg.d_rnn or cfg.d_model),
        "mlp": init_mlp(k2, cfg.d_model, cfg.d_ff, gated=True),
    }


def _attn_block_seq(p, cfg, policy, x, positions, cache, *, window, mixer,
                    decode=False):
    """Returns (x, new_cache, aux). cache may be None (train)."""
    _, norm = _norm_fns(cfg)
    h = norm(p["norm1"], x)
    if decode:
        o, cache = attn_mod.decode_attend(
            p["attn"], h, cache, positions, n_heads=cfg.n_heads,
            n_kv_heads=cfg.n_kv_heads, head_dim=cfg.head_dim_,
            rope_theta=cfg.rope_theta, window=window, policy=policy)
    else:
        o, (k, v) = attn_mod.attend(
            p["attn"], h, positions, n_heads=cfg.n_heads,
            n_kv_heads=cfg.n_kv_heads, head_dim=cfg.head_dim_,
            rope_theta=cfg.rope_theta, kind="causal", window=window,
            policy=policy, dense_max_seq=cfg.dense_attn_max,
            kv_block=cfg.kv_block)
        if cache is not None:
            cache = attn_mod.cache_from_prefill(
                k, v, positions, cache["k"].shape[2])  # (B,Hkv,S,Dh)
    x = x + o
    x = policy.act_residual(x)
    h = norm(p["norm2"], x)
    aux = jnp.zeros((), jnp.float32)
    if mixer == "moe":
        # inference (prefill: cache is not None; decode) is dropless so the
        # two cache paths route identically; training keeps capacity drops
        o, aux = moe_block(
            p["moe"], h, top_k=cfg.top_k, capacity_factor=cfg.capacity_factor,
            act=cfg.act, policy=policy, dispatch=cfg.moe_dispatch,
            normalize=cfg.normalize_topk,
            dropless=decode or cache is not None)
    else:
        o = mlp(p["mlp"], h, act=cfg.act, policy=policy)
    x = x + o
    x = policy.act_residual(x)
    return x, cache, aux


def _rec_block_seq(p, cfg, policy, x, state, *, decode=False):
    _, norm = _norm_fns(cfg)
    h = norm(p["norm1"], x)
    if decode:
        o, state = griffin_mod.recurrent_block_step(p["rec"], h[:, 0], state,
                                                    policy=policy)
        o = o[:, None]
    else:
        o, state = griffin_mod.recurrent_block_seq(
            p["rec"], h, state, chunk=cfg.rnn_chunk, policy=policy,
            unroll=not cfg.use_scan)
    x = x + o
    x = policy.act_residual(x)
    h = norm(p["norm2"], x)
    x = x + mlp(p["mlp"], h, act=cfg.act, policy=policy)
    x = policy.act_residual(x)
    return x, state


# ---------------------------------------------------------------------------
# Layer-stack plans per family
# ---------------------------------------------------------------------------


def _plan(cfg: ModelConfig):
    """Returns (scan_kinds, n_scan, tail_kinds). scan_kinds is the block-kind
    tuple of one scan group; the group repeats n_scan times; tail_kinds are
    unrolled trailing blocks (hybrid depth not divisible by the pattern)."""
    if cfg.family in ("dense", "vlm"):
        return ("attn_mlp",), cfg.n_layers, ()
    if cfg.family == "moe":
        return ("attn_moe",), cfg.n_layers, ()
    if cfg.family == "ssm":
        return ("rwkv",), cfg.n_layers, ()
    if cfg.family == "hybrid":
        pat = cfg.pattern or ("rec", "rec", "attn")
        kinds = tuple("attn_mlp" if k == "attn" else "rec_mlp" for k in pat)
        n = cfg.n_layers // len(pat)
        tail_n = cfg.n_layers - n * len(pat)
        return kinds, n, kinds[:tail_n]
    raise ValueError(cfg.family)


def _init_block(rng, cfg, kind):
    if kind == "attn_mlp":
        return _init_attn_block(rng, cfg, mixer="mlp")
    if kind == "attn_moe":
        return _init_attn_block(rng, cfg, mixer="moe")
    if kind == "rwkv":
        return rwkv_mod.init_rwkv_block(
            rng, cfg.d_model, cfg.d_ff, cfg.rwkv_heads, cfg.rwkv_head_dim)
    if kind == "rec_mlp":
        return _init_rec_block(rng, cfg)
    raise ValueError(kind)


def init_params(rng, cfg: ModelConfig):
    kinds, n_scan, tail = _plan(cfg)
    k_embed, k_layers, k_tail, k_head = jax.random.split(rng, 4)
    group_init = lambda r: {
        f"b{i}_{kind}": _init_block(jax.random.fold_in(r, i), cfg, kind)
        for i, kind in enumerate(kinds)
    }
    layers = jax.vmap(group_init)(jax.random.split(k_layers, n_scan))
    init_norm, _ = _norm_fns(cfg)
    params = {
        "embed": init_embed(k_embed, cfg.vocab, cfg.d_model),
        "layers": layers,
        "final_norm": init_norm(cfg.d_model),
    }
    if tail:
        params["tail"] = [
            _init_block(jax.random.fold_in(k_tail, i), cfg, kind)
            for i, kind in enumerate(tail)
        ]
    if not cfg.tie_embeddings:
        params["lm_head"] = dense_init(k_head, cfg.d_model, cfg.vocab)
    return params


# ---------------------------------------------------------------------------
# Caches / recurrent state
# ---------------------------------------------------------------------------


def _init_block_cache(cfg: ModelConfig, kind: str, batch: int, cache_len: int):
    if kind in ("attn_mlp", "attn_moe"):
        window = _window_for(cfg, kind)
        clen = min(cache_len, window) if window else cache_len
        return attn_mod.init_cache(batch, clen, cfg.n_kv_heads, cfg.head_dim_)
    if kind == "rwkv":
        return rwkv_mod.init_rwkv_state(
            batch, cfg.d_model, cfg.rwkv_heads, cfg.rwkv_head_dim)
    if kind == "rec_mlp":
        return griffin_mod.init_griffin_state(batch, cfg.d_rnn or cfg.d_model)
    raise ValueError(kind)


def _window_for(cfg: ModelConfig, kind: str):
    if cfg.family == "hybrid":
        return cfg.local_window
    return cfg.sliding_window


def init_cache(cfg: ModelConfig, batch: int, cache_len: int):
    kinds, n_scan, tail = _plan(cfg)
    group = {
        f"b{i}_{kind}": _init_block_cache(cfg, kind, batch, cache_len)
        for i, kind in enumerate(kinds)
    }
    stacked = jax.tree.map(
        lambda x: jnp.broadcast_to(x, (n_scan,) + x.shape).copy(), group)
    out = {"layers": stacked}
    if tail:
        out["tail"] = [
            _init_block_cache(cfg, kind, batch, cache_len)
            for kind in tail
        ]
    return out


# ---------------------------------------------------------------------------
# Forward passes
# ---------------------------------------------------------------------------


def _apply_block(p, cfg, policy, kind, x, positions, cache, decode):
    if kind in ("attn_mlp", "attn_moe"):
        mixer = "moe" if kind == "attn_moe" else "mlp"
        return _attn_block_seq(p, cfg, policy, x, positions, cache,
                               window=_window_for(cfg, kind), mixer=mixer,
                               decode=decode)
    if kind == "rwkv":
        if cache is None:  # training: fresh zero state
            cache = rwkv_mod.init_rwkv_state(
                x.shape[0], cfg.d_model, cfg.rwkv_heads, cfg.rwkv_head_dim)
        x, st = (rwkv_mod.rwkv_block_step(
            p, x[:, 0], cache, n_heads=cfg.rwkv_heads,
            head_dim=cfg.rwkv_head_dim, policy=policy)
            if decode else
            rwkv_mod.rwkv_block_seq(
                p, x, cache, n_heads=cfg.rwkv_heads,
                head_dim=cfg.rwkv_head_dim, chunk=cfg.rwkv_chunk,
                policy=policy, unroll=not cfg.use_scan))
        if decode:
            x = x[:, None]
        return x, st, jnp.zeros((), jnp.float32)
    if kind == "rec_mlp":
        if cache is None:  # training: fresh zero state
            cache = griffin_mod.init_griffin_state(
                x.shape[0], cfg.d_rnn or cfg.d_model)
        x, st = _rec_block_seq(p, cfg, policy, x, cache, decode=decode)
        return x, st, jnp.zeros((), jnp.float32)
    raise ValueError(kind)


def _run_stack(cfg, policy, params, x, positions, caches, decode):
    """Scan over the layer stack; returns (x, new_caches, aux_sum)."""
    kinds, n_scan, tail = _plan(cfg)

    def group_body(carry, inp):
        x, aux = carry
        p_group, c_group = inp
        new_caches = {}
        for i, kind in enumerate(kinds):
            key = f"b{i}_{kind}"
            cache_i = None if c_group is None else c_group[key]
            x, new_c, a = _apply_block(
                p_group[key], cfg, policy, kind, x, positions, cache_i,
                decode)
            new_caches[key] = new_c if new_c is not None else 0
            aux = aux + a
        return (x, aux), new_caches

    body = group_body
    if cfg.remat and not decode:
        body = jax.checkpoint(group_body)

    def scan_or_unroll(body_fn, init, xs, length):
        if cfg.use_scan:
            return jax.lax.scan(body_fn, init, xs)
        carry, ys = init, []
        for i in range(length):
            x_i = jax.tree.map(lambda a: a[i], xs)
            carry, y = body_fn(carry, x_i)
            ys.append(y)
        stack = (jax.tree.map(lambda *a: jnp.stack(a), *ys)
                 if ys and ys[0] is not None else None)
        return carry, stack

    kinds_n = n_scan
    if caches is None:
        def body_nocache(carry, p_group):
            return body(carry, (p_group, None))
        (x, aux), _ = scan_or_unroll(
            body_nocache, (x, jnp.zeros((), jnp.float32)), params["layers"],
            kinds_n)
        new_layer_caches = None
    elif decode:
        # Decode memory discipline: the stacked cache lives in the scan
        # CARRY with per-layer dynamic in-place updates. XLA aliases while
        # carries, so exactly ONE cache buffer exists. Passing it as xs/ys
        # keeps TWO (input stack + output stack) — measured +9 GiB/device
        # on qwen2-72b decode_32k (EXPERIMENTS.md §Perf, iteration 0b).
        stacked = caches["layers"]

        def decode_body(carry, p_group):
            x, aux, cs, i = carry
            c_group = jax.tree.map(
                lambda c: jax.lax.dynamic_index_in_dim(c, i, 0,
                                                       keepdims=False), cs)
            (x, aux), new_group = body((x, aux), (p_group, c_group))
            cs = jax.tree.map(
                lambda c, n: jax.lax.dynamic_update_index_in_dim(
                    c, n.astype(c.dtype), i, 0), cs, new_group)
            return (x, aux, cs, i + 1), None

        if cfg.use_scan:
            (x, aux, stacked, _), _ = jax.lax.scan(
                decode_body,
                (x, jnp.zeros((), jnp.float32), stacked,
                 jnp.zeros((), jnp.int32)), params["layers"])
        else:
            carry = (x, jnp.zeros((), jnp.float32), stacked,
                     jnp.zeros((), jnp.int32))
            for i in range(kinds_n):
                carry, _ = decode_body(
                    carry, jax.tree.map(lambda a: a[i], params["layers"]))
            x, aux, stacked, _ = carry
        new_layer_caches = stacked
    else:
        (x, aux), new_layer_caches = scan_or_unroll(
            body, (x, jnp.zeros((), jnp.float32)),
            (params["layers"], caches["layers"]), kinds_n)

    new_tail = []
    if tail:
        for i, kind in enumerate(tail):
            c = None if caches is None else caches["tail"][i]
            x, new_c, a = _apply_block(
                params["tail"][i], cfg, policy, kind, x, positions, c, decode)
            aux = aux + a
            new_tail.append(new_c)

    if caches is None:
        return x, None, aux
    out_caches = {"layers": new_layer_caches}
    if tail:
        out_caches["tail"] = new_tail
    return x, out_caches, aux


def _embed_inputs(cfg, policy, params, tokens, vision_embeds=None):
    x = embed(params["embed"], tokens, COMPUTE_DTYPE)
    if cfg.family == "vlm" and vision_embeds is not None:
        x = jnp.concatenate([vision_embeds.astype(COMPUTE_DTYPE), x], axis=1)
    return policy.act_residual(x)


def _logits(cfg, params, x):
    _, norm = _norm_fns(cfg)
    x = norm(params["final_norm"], x)
    return unembed(params["embed"], params.get("lm_head"), x)


def apply_train(cfg: ModelConfig, policy: Policy, params, tokens,
                vision_embeds=None):
    """tokens: (B, S_text) int32 → (logits (B, S, V) fp32, aux)."""
    x = _embed_inputs(cfg, policy, params, tokens, vision_embeds)
    positions = jnp.arange(x.shape[1])[None, :]
    x, _, aux = _run_stack(cfg, policy, params, x, positions, None,
                           decode=False)
    logits = _logits(cfg, params, x)
    return logits.astype(jnp.float32), aux


def prefill(cfg: ModelConfig, policy: Policy, params, tokens, cache_len,
            vision_embeds=None):
    """Full-sequence inference producing the KV/recurrent cache.

    Returns (last-position logits (B, V), caches)."""
    x = _embed_inputs(cfg, policy, params, tokens, vision_embeds)
    b, s = x.shape[:2]
    positions = jnp.arange(s)[None, :]
    caches = init_cache(cfg, b, cache_len)
    x, caches, _ = _run_stack(cfg, policy, params, x, positions, caches,
                              decode=False)
    logits = _logits(cfg, params, x[:, -1:])
    return logits[:, 0].astype(jnp.float32), caches


def decode_step(cfg: ModelConfig, policy: Policy, params, token, caches, pos):
    """token: (B, 1) int32; pos: (B,) absolute positions.

    Returns (logits (B, V), new caches)."""
    x = embed(params["embed"], token, COMPUTE_DTYPE)
    x, caches, _ = _run_stack(cfg, policy, params, x, pos[:, None], caches,
                              decode=True)
    logits = _logits(cfg, params, x)
    return logits[:, 0].astype(jnp.float32), caches
