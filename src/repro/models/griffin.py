"""Griffin / RecurrentGemma (arXiv:2402.19427): RG-LRU + local attention, 1:2.

Temporal pattern: repeating (recurrent, recurrent, local-attention) groups.
Recurrent block: gated dual-branch — gelu(x·W_y) ⊙ RG-LRU(conv1d(x·W_x)),
projected back by W_o. RG-LRU is a per-channel gated diagonal recurrence:

    r_t = σ(x_t·W_a + b_a)          (recurrence gate)
    i_t = σ(x_t·W_i + b_i)          (input gate)
    log a_t = -c · softplus(Λ) ⊙ r_t             (c = 8)
    h_t = a_t ⊙ h_{t-1} + sqrt(1 - a_t²) ⊙ (i_t ⊙ x_t)

evaluated by the exact chunked diagonal engine (models/recurrence.py).
Deviation noted in DESIGN.md: gate projections are full d_rnn×d_rnn linears
(the reference uses block-diagonal); identical cost profile at this width.

Decode state per layer: conv tail (B, 3, d_rnn) + LRU h (B, d_rnn); the
attention blocks carry a ``local_window`` rolling KV cache — together this
is why recurrentgemma qualifies for ``long_500k``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import dense_init
from repro.models.recurrence import chunked_diag_recurrence
from repro.sharding import Policy

RG_LRU_C = 8.0
CONV_W = 4


def init_rglru(rng, d_rnn, dtype=jnp.float32):
    ks = jax.random.split(rng, 3)
    # Λ init so that a^c ∈ (0.9, 0.999) roughly — griffin appendix
    lam = jax.random.uniform(ks[0], (d_rnn,), minval=0.9, maxval=0.999)
    lam = jnp.log(jnp.expm1(-jnp.log(lam) / RG_LRU_C))    # inverse softplus
    return {
        "w_a": dense_init(ks[1], d_rnn, d_rnn, dtype),
        "b_a": jnp.zeros((d_rnn,), jnp.float32),
        "w_i": dense_init(ks[2], d_rnn, d_rnn, dtype),
        "b_i": jnp.zeros((d_rnn,), jnp.float32),
        "lam": lam,
    }


def _rglru_coeffs(p, x):
    """x: (…, d_rnn) → (a, b) of the diagonal recurrence, fp32."""
    xf = x.astype(jnp.float32)
    r = jax.nn.sigmoid(xf @ p["w_a"].astype(jnp.float32) + p["b_a"])
    i = jax.nn.sigmoid(xf @ p["w_i"].astype(jnp.float32) + p["b_i"])
    log_a = -RG_LRU_C * jax.nn.softplus(p["lam"]) * r
    a = jnp.exp(log_a)
    # sqrt(1 - a²) via expm1 for stability near a≈1
    mult = jnp.sqrt(-jnp.expm1(2.0 * log_a))
    b = mult * (i * xf)
    return a, b


def init_recurrent_block(rng, d, d_rnn, dtype=jnp.float32):
    ks = jax.random.split(rng, 4)
    return {
        "w_y": dense_init(ks[0], d, d_rnn, dtype),
        "w_x": dense_init(ks[1], d, d_rnn, dtype),
        "conv_w": 0.01 * jax.random.normal(ks[2], (CONV_W, d_rnn), dtype),
        "conv_b": jnp.zeros((d_rnn,), jnp.float32),
        "rglru": init_rglru(ks[3], d_rnn, dtype),
        "w_o": dense_init(jax.random.fold_in(rng, 9), d_rnn, d, dtype),
    }


def _causal_conv_seq(p, x, tail):
    """Depthwise causal conv width 4. x: (B,T,dr); tail: (B,3,dr) history."""
    full = jnp.concatenate([tail.astype(x.dtype), x], axis=1)
    out = sum(
        full[:, CONV_W - 1 - i: full.shape[1] - i] * p["conv_w"][CONV_W - 1 - i].astype(x.dtype)
        for i in range(CONV_W)
    )
    new_tail = full[:, -(CONV_W - 1):]
    return out + p["conv_b"].astype(x.dtype), new_tail


def recurrent_block_seq(p, x, state, *, chunk, policy: Policy,
                        unroll=False):
    """x: (B,T,d); state: {"conv": (B,3,dr), "h": (B,dr)}."""
    y = jax.nn.gelu(x @ p["w_y"].astype(x.dtype))
    xr = x @ p["w_x"].astype(x.dtype)
    xr, conv_tail = _causal_conv_seq(p, xr, state["conv"])
    a, b = _rglru_coeffs(p["rglru"], xr)
    hs, hT = chunked_diag_recurrence(
        a.swapaxes(0, 1), b.swapaxes(0, 1), state["h"].astype(jnp.float32),
        chunk=chunk, unroll=unroll)
    h = hs.swapaxes(0, 1).astype(x.dtype)                 # (B,T,dr)
    out = (h * y) @ p["w_o"].astype(x.dtype)
    return out, {"conv": conv_tail.astype(jnp.float32), "h": hT}


def recurrent_block_step(p, x, state, *, policy: Policy):
    """x: (B, d) single token."""
    y = jax.nn.gelu(x @ p["w_y"].astype(x.dtype))
    xr = x @ p["w_x"].astype(x.dtype)
    hist = jnp.concatenate([state["conv"].astype(x.dtype), xr[:, None]], 1)
    conv = sum(hist[:, -1 - i] * p["conv_w"][CONV_W - 1 - i].astype(x.dtype)
               for i in range(CONV_W)) + p["conv_b"].astype(x.dtype)
    a, b = _rglru_coeffs(p["rglru"], conv)
    h = a * state["h"].astype(jnp.float32) + b
    out = (h.astype(x.dtype) * y) @ p["w_o"].astype(x.dtype)
    return out, {"conv": hist[:, 1:].astype(jnp.float32), "h": h}


def init_griffin_state(batch, d_rnn, dtype=jnp.float32):
    return {"conv": jnp.zeros((batch, CONV_W - 1, d_rnn), jnp.float32),
            "h": jnp.zeros((batch, d_rnn), jnp.float32)}
