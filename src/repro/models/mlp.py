"""Dense MLPs: SwiGLU (llama-family) and GELU (whisper)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import activation, dense_init
from repro.sharding import Policy


def init_mlp(rng, d_model, d_ff, *, gated=True, dtype=jnp.float32):
    ks = jax.random.split(rng, 3)
    p = {
        "w_up": dense_init(ks[0], d_model, d_ff, dtype),
        "w_down": dense_init(ks[1], d_ff, d_model, dtype),
    }
    if gated:
        p["w_gate"] = dense_init(ks[2], d_model, d_ff, dtype)
    return p


def mlp(p, x, *, act="silu", policy: Policy):
    fn = activation(act)
    up = x @ p["w_up"].astype(x.dtype)
    if "w_gate" in p:
        gate = x @ p["w_gate"].astype(x.dtype)
        h = fn(gate) * up
    else:
        h = fn(up)
    h = policy.act_btd_tp(h)
    return h @ p["w_down"].astype(x.dtype)
