"""Recurrence substrate for the SSM/hybrid families.

Two engines, both exact (tests pin them against naive sequential scans):

  * ``chunked_diag_recurrence`` — h_t = a_t ⊙ h_{t-1} + b_t over (T, B, D),
    evaluated as lax.scan over chunks with an associative scan inside each
    chunk. Only chunk-boundary states live across iterations, bounding
    memory at O(C·B·D); the chunk loop is a declared 'chunks' roofline
    scale-dim (DESIGN.md §6). Used by RG-LRU.

  * ``chunked_matrix_recurrence`` — GLA/RWKV-style matrix-state recurrence
      S_t = diag(w_t) S_{t-1} + k_t^T v_t,   o_t = r_t·S_{t-1} + (r_t⊙u⊙k_t)·v_t
    evaluated chunk-parallel: intra-chunk pairwise decay ratios are computed
    in log space where every exponent is ≤ 0 (la is monotone decreasing), so
    the form is numerically stable without GLA's secondary chunking. The
    (C, C, Dk) relative-decay tensor is materialised per chunk — chunk size
    bounds VMEM/HBM temp, default 32. Turns the recurrence into MXU matmuls
    instead of T outer-product steps. Used by RWKV6.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def _pad_time(x, chunk):
    t = x.shape[0]
    pad = (-t) % chunk
    if pad:
        x = jnp.concatenate(
            [x, jnp.zeros((pad,) + x.shape[1:], x.dtype)], axis=0)
    return x, t


def diag_recurrence_ref(a, b, h0):
    """Naive sequential oracle. a, b: (T, B, D); h0: (B, D)."""
    def step(h, ab):
        at, bt = ab
        h = at * h + bt
        return h, h
    hT, hs = jax.lax.scan(step, h0, (a, b))
    return hs, hT


def _scan_chunks(step, init, xs, *, unroll):
    """lax.scan over chunk tuples, or a Python loop when ``unroll`` —
    the roofline probes unroll so cost_analysis counts every chunk
    (while bodies are counted once; DESIGN.md §6)."""
    if not unroll:
        return jax.lax.scan(step, init, xs)
    carry, ys = init, []
    n = jax.tree_util.tree_leaves(xs)[0].shape[0]
    for i in range(n):
        carry, y = step(carry, jax.tree.map(lambda x: x[i], xs))
        ys.append(y)
    return carry, jnp.concatenate([y[None] for y in ys], axis=0)


def chunked_diag_recurrence(a, b, h0, *, chunk=256, unroll=False):
    """Exact chunked evaluation of h_t = a_t h_{t-1} + b_t.

    a, b: (T, B, D) — a in (0, 1]; h0: (B, D). Returns (hs (T,B,D), hT).
    """
    (a_p, t_orig) = _pad_time(a, chunk)
    # padded steps must be identity: a=1, b=0
    if a_p.shape[0] != a.shape[0]:
        pad = a_p.shape[0] - a.shape[0]
        ones = jnp.ones((pad,) + a.shape[1:], a.dtype)
        a_p = jnp.concatenate([a, ones], axis=0)
    b_p, _ = _pad_time(b, chunk)
    k = a_p.shape[0] // chunk
    a_c = a_p.reshape(k, chunk, *a.shape[1:])
    b_c = b_p.reshape(k, chunk, *b.shape[1:])

    def combine(x, y):
        ax, bx = x
        ay, by = y
        return ax * ay, bx * ay + by

    def chunk_step(h, ab):
        ac, bc = ab
        # associative scan within the chunk (log-depth, fully counted HLO)
        aa, bb = jax.lax.associative_scan(combine, (ac, bc), axis=0)
        hs = aa * h[None] + bb
        return hs[-1], hs

    hT, hs = _scan_chunks(chunk_step, h0, (a_c, b_c), unroll=unroll)
    hs = hs.reshape(k * chunk, *a.shape[1:])[:t_orig]
    return hs, hT


def matrix_recurrence_ref(r, k, v, w, u, s0):
    """Naive oracle.  r,k,w: (T,B,H,Dk); v: (T,B,H,Dv); u: (H,Dk);
    s0: (B,H,Dk,Dv).  Returns (o (T,B,H,Dv), sT)."""
    def step(s, inp):
        rt, kt, vt, wt = inp
        kv = kt[..., :, None] * vt[..., None, :]            # (B,H,Dk,Dv)
        o = jnp.einsum("bhk,bhkv->bhv", rt, s) + jnp.einsum(
            "bhk,hk,bhkv->bhv", rt, u, kv)
        s = wt[..., None] * s + kv
        return s, o
    sT, o = jax.lax.scan(step, s0, (r, k, v, w))
    return o, sT


def chunked_matrix_recurrence(r, k, v, w, u, s0, *, chunk=32, unroll=False):
    """Exact chunk-parallel evaluation of the RWKV6 recurrence (fp32 core).

    Shapes as in ``matrix_recurrence_ref``. All decay exponents are computed
    as within-chunk differences la_i - la_j with i ≥ j ⇒ exponent ≤ 0.
    """
    t, b, h, dk = r.shape
    dv = v.shape[-1]
    pad = (-t) % chunk
    if pad:
        z = lambda x: jnp.concatenate(
            [x, jnp.zeros((pad,) + x.shape[1:], x.dtype)], 0)
        r, k, v = z(r), z(k), z(v)
        w = jnp.concatenate([w, jnp.ones((pad,) + w.shape[1:], w.dtype)], 0)
    n = r.shape[0] // chunk
    rc = r.reshape(n, chunk, b, h, dk).astype(jnp.float32)
    kc = k.reshape(n, chunk, b, h, dk).astype(jnp.float32)
    vc = v.reshape(n, chunk, b, h, dv).astype(jnp.float32)
    wc = w.reshape(n, chunk, b, h, dk).astype(jnp.float32)
    uf = u.astype(jnp.float32)

    def chunk_step(s, inp):
        rt, kt, vt, wt = inp                               # (C,B,H,·)
        la = jnp.cumsum(jnp.log(jnp.maximum(wt, 1e-30)), axis=0)  # (C,B,H,Dk)
        la_prev = la - jnp.log(jnp.maximum(wt, 1e-30))     # la_{t-1}
        # cross-chunk contribution: o_t += (r_t ⊙ a_{t-1}) S_0
        q_tilde = rt * jnp.exp(la_prev)
        o = jnp.einsum("cbhk,bhkv->cbhv", q_tilde, s)
        # intra-chunk: P[t,τ] = Σ_d r_td k_τd exp(la_prev[t,d] - la[τ,d]), τ<t
        diff = la_prev[:, None] - la[None, :]              # (C,C,B,H,Dk) ≤ 0 for τ<t
        tt = jnp.arange(chunk)
        causal = (tt[:, None] > tt[None, :])
        diff = jnp.where(causal[:, :, None, None, None], diff, 0.0)
        pmat = jnp.einsum("cbhk,sbhk,csbhk->csbh", rt, kt, jnp.exp(diff))
        pmat = jnp.where(causal[:, :, None, None], pmat, 0.0)
        o = o + jnp.einsum("csbh,sbhv->cbhv", pmat, vt)
        # diagonal bonus term: ((r_t ⊙ u) · k_t) v_t
        diag = jnp.einsum("cbhk,hk,cbhk->cbh", rt, uf, kt)
        o = o + diag[..., None] * vt
        # state update to chunk end: S' = diag(a_C) S + Σ_τ diag(a_C/a_τ) k_τ v_τ
        a_end = jnp.exp(la[-1])                            # (B,H,Dk)
        k_scaled = kt * jnp.exp(la[-1][None] - la)         # (C,B,H,Dk), exp ≤ 1
        s_new = a_end[..., None] * s + jnp.einsum(
            "cbhk,cbhv->bhkv", k_scaled, vt)
        return s_new, o

    sT, o = _scan_chunks(chunk_step, s0.astype(jnp.float32),
                         (rc, kc, vc, wc), unroll=unroll)
    o = o.reshape(n * chunk, b, h, dv)[:t]
    return o.astype(v.dtype), sT


def matrix_recurrence_step(r, k, v, w, u, s):
    """Single decode step. r,k,w: (B,H,Dk); v: (B,H,Dv); s: (B,H,Dk,Dv)."""
    r = r.astype(jnp.float32)
    k = k.astype(jnp.float32)
    v32 = v.astype(jnp.float32)
    w = w.astype(jnp.float32)
    kv = k[..., :, None] * v32[..., None, :]
    o = jnp.einsum("bhk,bhkv->bhv", r, s) + jnp.einsum(
        "bhk,hk,bhkv->bhv", r, u.astype(jnp.float32), kv)
    s = w[..., None] * s + kv
    return o.astype(v.dtype), s
