"""Mixture-of-Experts: grouped top-k routing, shared experts, two dispatch
engines.

Grouping (GShard/Switch semantics): tokens are reshaped (B, S, d) →
(G, T_g, d) with G = batch size, and ALL routing state (ranks, capacity,
dispatch tables) is per-group. The group dim carries the batch sharding, so
routing never synchronizes across devices — a global-cumsum dispatch was
measured at 80+ GiB/device on the 32k-prefill cells (EXPERIMENTS.md §Perf
iteration 0c); grouped dispatch is the fix and the industry default.

Expert sharding (DESIGN.md §4): expert weights (E, d, f) put ``f`` on the
``model`` axis (TP inside every expert — no expert-count divisibility
constraint; 8 or 60 experts both map onto 16-way TP) and ``d`` on ``data``
(FSDP). The collective profile equals the dense-MLP TP profile.

Dispatch engines (identical outputs incl. per-group drop behaviour):
  * ``einsum`` — GShard one-hot dispatch/combine einsums (baseline;
    O(T_g·E·C) extra work);
  * ``sort``   — capacity-slot scatter/gather (Megablocks-flavoured,
    O(T_g·k·d) data movement; the beyond-baseline engine).

Capacity: C = max(1, cf·T_g·k/E) per group. The capacity-slot algebra is
the same fixed-capacity scatter as the paper's inclusion lists
(core/indexing.py) — see DESIGN.md §5.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.common import activation, dense_init
from repro.sharding import DATA, Policy, current_mesh, shard_map_compat


def init_moe(rng, d_model, d_ff_expert, n_experts, *, n_shared=0,
             d_ff_shared=None, dtype=jnp.float32):
    ks = jax.random.split(rng, 8)
    p = {
        "router": dense_init(ks[0], d_model, n_experts, jnp.float32),
        "w_gate": dense_init(ks[1], n_experts * d_model, d_ff_expert,
                             dtype).reshape(n_experts, d_model, d_ff_expert),
        "w_up": dense_init(ks[2], n_experts * d_model, d_ff_expert,
                           dtype).reshape(n_experts, d_model, d_ff_expert),
        "w_down": dense_init(ks[3], n_experts * d_ff_expert, d_model,
                             dtype).reshape(n_experts, d_ff_expert, d_model),
    }
    if n_shared:
        d_sh = d_ff_shared or n_shared * d_ff_expert
        p["shared"] = {
            "w_gate": dense_init(ks[4], d_model, d_sh, dtype),
            "w_up": dense_init(ks[5], d_model, d_sh, dtype),
            "w_down": dense_init(ks[6], d_sh, d_model, dtype),
        }
        p["shared_gate"] = dense_init(ks[7], d_model, 1, dtype)
    return p


def _route(p, x, top_k, *, normalize=True):
    """x: (G, T, d) → (gates (G,T,k), experts (G,T,k), aux)."""
    logits = x.astype(jnp.float32) @ p["router"]
    probs = jax.nn.softmax(logits, axis=-1)               # (G, T, E)
    gates, experts = jax.lax.top_k(probs, top_k)
    if normalize:
        gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)
    e = probs.shape[-1]
    me = probs.mean((0, 1))
    ce = jax.nn.one_hot(experts[..., 0], e).mean((0, 1))
    # Switch aux loss factors; reduced to a scalar by the caller so the
    # sharded path can average me/ce across shards BEFORE the product
    return gates, experts, (me, ce)


def _slots(experts, top_k, e, capacity):
    """Per-group rank of each (token, k) within its expert.

    experts: (G, T, k) → (slot (G,T,k), keep (G,T,k)).

    Memory-light ranking: a (G,T·k,E) one-hot cumsum costs 7.8 GiB/device
    at qwen2-moe scale (E=60) — EXPERIMENTS.md §Perf iteration 0d. Instead:
    stable argsort by expert id, rank = position − start-of-expert-run,
    O(G·T·k) memory. Stable sort ⇒ identical token-order ranks (and drops)
    as the cumsum formulation.
    """
    g, t, k = experts.shape
    tk = t * k
    exp_f = experts.reshape(g, tk)
    gi = jnp.arange(g)[:, None]
    order = jnp.argsort(exp_f, axis=1, stable=True)        # (G, TK)
    sorted_exp = jnp.take_along_axis(exp_f, order, axis=1)
    counts = jnp.zeros((g, e), jnp.int32).at[gi, exp_f].add(1)
    starts = jnp.cumsum(counts, axis=1) - counts           # exclusive
    rank_sorted = (jnp.arange(tk, dtype=jnp.int32)[None]
                   - jnp.take_along_axis(starts, sorted_exp, axis=1))
    slot = jnp.zeros((g, tk), jnp.int32).at[gi, order].set(rank_sorted)
    slot = slot.reshape(g, t, k)
    return slot, slot < capacity


def _expert_ffn(p, h, act_fn, policy: Policy):
    """h: (G, E, C, d) → (G, E, C, d) through per-expert SwiGLU (TP on f).

    The output is constrained to d@model: the w_down contraction over
    f@model then resolves as reduce-scatter-sized traffic instead of a
    full (G,E,C,d) all-reduce — and, crucially, the combine-gather's
    BACKWARD scatter-add stays model-local (was a 640 MB fp32 all-reduce
    per layer per microbatch on mixtral train_4k — §Perf hillclimb B).
    """
    gate = jnp.einsum("gecd,edf->gecf", h, p["w_gate"].astype(h.dtype))
    up = jnp.einsum("gecd,edf->gecf", h, p["w_up"].astype(h.dtype))
    mid = act_fn(gate) * up
    if policy.active:
        mid = jax.lax.with_sharding_constraint(
            mid, jax.sharding.PartitionSpec(policy.b, None, None,
                                            policy.model_axis))
    out = jnp.einsum("gecf,efd->gecd", mid, p["w_down"].astype(h.dtype))
    if policy.active:
        out = jax.lax.with_sharding_constraint(
            out, jax.sharding.PartitionSpec(policy.b, None, None,
                                            policy.model_axis))
    return out


def moe_einsum(p, x, *, top_k, capacity, act="silu", policy: Policy,
               normalize=True):
    """GShard one-hot dispatch. x: (G, T, d) → (out (G,T,d), aux)."""
    g, t, d = x.shape
    e = p["router"].shape[-1]
    gates, experts, aux = _route(p, x, top_k, normalize=normalize)
    slot, keep = _slots(experts, top_k, e, capacity)
    oh_e = jax.nn.one_hot(experts, e, dtype=x.dtype)      # (G,T,k,E)
    oh_c = jax.nn.one_hot(jnp.where(keep, slot, capacity), capacity,
                          dtype=x.dtype)                  # (G,T,k,C)
    disp = jnp.einsum("gtke,gtkc->gtec", oh_e, oh_c)      # (G,T,E,C)
    h = jnp.einsum("gtec,gtd->gecd", disp, x)
    out_e = _expert_ffn(p, h, activation(act), policy)
    comb = jnp.einsum("gtke,gtkc,gtk->gtec", oh_e, oh_c,
                      gates.astype(x.dtype))
    out = jnp.einsum("gtec,gecd->gtd", comb, out_e)
    return out, aux


def moe_sort(p, x, *, top_k, capacity, act="silu", policy: Policy,
             normalize=True):
    """Capacity-slot scatter dispatch (no O(T·E·C) einsum). x: (G, T, d)."""
    g, t, d = x.shape
    e = p["router"].shape[-1]
    gates, experts, aux = _route(p, x, top_k, normalize=normalize)
    slot, keep = _slots(experts, top_k, e, capacity)

    exp_f = experts.reshape(g, t * top_k)
    slot_f = jnp.where(keep, slot, capacity).reshape(g, t * top_k)
    tok_f = jnp.broadcast_to(
        jnp.repeat(jnp.arange(t), top_k)[None], (g, t * top_k))
    # scatter token ids into per-group (E, C+1) slot tables
    table = jnp.full((g, e, capacity + 1), t, jnp.int32)
    gi = jnp.arange(g)[:, None]
    table = table.at[gi, exp_f, slot_f].set(tok_f.astype(jnp.int32),
                                            mode="drop")
    table = table[..., :capacity]                         # (G, E, C)
    x_pad = jnp.concatenate([x, jnp.zeros((g, 1, d), x.dtype)], axis=1)
    h = jnp.take_along_axis(
        x_pad[:, :, None, :],                             # (G, T+1, 1, d)
        table.reshape(g, e * capacity, 1, 1).clip(0, t),  # indices
        axis=1).reshape(g, e, capacity, d)
    out_e = _expert_ffn(p, h, activation(act), policy)
    out_flat = out_e.reshape(g, e * capacity, d)
    lin = jnp.where(keep, experts * capacity + slot,
                    e * capacity).reshape(g, t * top_k)
    out_pad = jnp.concatenate(
        [out_flat, jnp.zeros((g, 1, d), x.dtype)], axis=1)
    per_k = jnp.take_along_axis(
        out_pad[:, :, None, :], lin.reshape(g, t * top_k, 1, 1), axis=1)
    per_k = per_k.reshape(g, t, top_k, d)
    out = jnp.einsum("gtkd,gtk->gtd", per_k, gates.astype(x.dtype))
    return out, aux


def _moe_shard_map(p, xg, *, top_k, capacity, act, policy: Policy,
                   dispatch, normalize):
    """Explicit-collective MoE (hillclimb B, EXPERIMENTS.md §Perf).

    GSPMD placed the TP all-reduce at the capacity-inflated (G,E,C,d)
    expert output — and its BACKWARD emitted a fp32 all-reduce of the
    dispatch scatter-add (640 MB/layer/microbatch on mixtral train_4k).
    Here collectives are explicit and token-sized:

      * expert weights: one tiled all-gather over `data` (FSDP); its
        transpose is automatically a reduce-scatter of the weight grads;
      * routing/dispatch/ffn/combine: fully local (d is full, f is the
        local model shard — contraction over f-chunk makes the combined
        output a partial sum);
      * ONE psum over `model` of the (G_local, T, d) combined output.
    """
    mesh = current_mesh()
    bb = policy.b
    m_axis = policy.model_axis
    engine = {"einsum": moe_einsum, "sort": moe_sort}[dispatch]
    local_policy = Policy.none()

    def body(xl, router, wg, wu, wd):
        # weights arrive with d sharded over `data` (FSDP): gather d —
        # w_gate/w_up (E, d/|data|, f_loc) axis=1; w_down (E, f_loc, d/…) axis=2
        p_local = {
            "router": jax.lax.all_gather(router, DATA, axis=0, tiled=True),
            "w_gate": jax.lax.all_gather(wg, DATA, axis=1, tiled=True),
            "w_up": jax.lax.all_gather(wu, DATA, axis=1, tiled=True),
            "w_down": jax.lax.all_gather(wd, DATA, axis=2, tiled=True),
        }
        out, (me, ce) = engine(p_local, xl, top_k=top_k, capacity=capacity,
                               act=act, policy=local_policy,
                               normalize=normalize)
        out = jax.lax.psum(out, m_axis)        # token-sized TP reduce
        if bb:                                  # exact global aux stats
            me = jax.lax.pmean(me, bb)
            ce = jax.lax.pmean(ce, bb)
        return out, me, ce

    out, me, ce = shard_map_compat(
        body, mesh=mesh,
        in_specs=(P(bb, None, None),                 # xg: groups on batch axes
                  P(DATA, None),                     # router (d, E)
                  P(None, DATA, m_axis),             # w_gate (E, d, f)
                  P(None, DATA, m_axis),             # w_up
                  P(None, m_axis, DATA)),            # w_down (E, f, d)
        out_specs=(P(bb, None, None), P(), P()),
    )(xg, p["router"], p["w_gate"], p["w_up"], p["w_down"])
    return out, (me, ce)


def moe_block(p, x, *, top_k, capacity_factor, act="silu", policy: Policy,
              dispatch="sort", normalize=True, num_groups=None,
              use_shard_map=True, dropless=False):
    """x: (B, S, d) → (out, aux). Groups = batch rows (GShard semantics);
    shared experts (if any) always active.

    ``dropless=True`` sizes capacity to the per-group token count — no
    token can ever overflow its expert (top-k experts are distinct, so an
    expert receives at most T_g tokens/group). Inference (prefill + decode)
    runs dropless: capacity dropping is a *training* regularizer, and a
    prefill that drops tokens can never agree with step-by-step decode,
    where each single-token group trivially fits (the mixtral
    prefill↔decode consistency bug). Costs up to E/(cf·k)× more expert-FFN
    buffer at prefill; decode (T_g = 1) is unchanged.
    """
    b, s, d = x.shape
    g = num_groups or b
    tg = (b * s) // g
    e = p["router"].shape[-1]
    capacity = tg if dropless else max(1, int(capacity_factor * tg * top_k / e))
    xg = x.reshape(g, tg, d)
    if policy.active:
        xg = jax.lax.with_sharding_constraint(
            xg, jax.sharding.PartitionSpec(policy.b, None, None))
    if policy.active and use_shard_map and policy.model_axis is not None:
        out, (me, ce) = _moe_shard_map(
            p, xg, top_k=top_k, capacity=capacity, act=act, policy=policy,
            dispatch=dispatch, normalize=normalize)
    else:
        fn = {"einsum": moe_einsum, "sort": moe_sort}[dispatch]
        out, (me, ce) = fn(p, xg, top_k=top_k, capacity=capacity, act=act,
                           policy=policy, normalize=normalize)
    aux = e * jnp.sum(me * ce)                   # Switch load-balance loss
    out = out.reshape(b, s, d)
    if "shared" in p:
        from repro.models.mlp import mlp
        sh = mlp(p["shared"], x, act=act, policy=policy)
        sg = jax.nn.sigmoid(x @ p["shared_gate"].astype(x.dtype))
        out = out + sg * sh
    return out, aux
