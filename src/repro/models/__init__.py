"""LM-family model zoo substrate (assigned architectures)."""
