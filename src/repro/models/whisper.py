"""Whisper-medium backbone (arXiv:2212.04356): encoder-decoder transformer.

Per the assignment spec, the conv/audio frontend is a STUB — ``input_specs``
provides precomputed frame embeddings (B, enc_seq, d). The encoder adds
sinusoidal positions and runs full (bidirectional) attention; the decoder is
a standard causal transformer with cross-attention to encoder output and
learned positions. GELU MLPs, pre-LayerNorm (faithful to the reference).

Shape-cell note (DESIGN.md §5): prefill/decode shapes exercise the DECODER
sequence; position tables are sized from the requested shape. Decode caches:
self-attn KV (cache_len) + precomputed cross-attn KV (enc_seq).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as attn_mod
from repro.models.common import (
    dense_init,
    embed,
    init_embed,
    init_layernorm,
    layernorm,
    sinusoidal_positions,
)
from repro.models.mlp import init_mlp, mlp
from repro.sharding import Policy

COMPUTE_DTYPE = jnp.bfloat16


def _init_enc_layer(rng, cfg):
    k1, k2 = jax.random.split(rng)
    return {
        "norm1": init_layernorm(cfg.d_model),
        "norm2": init_layernorm(cfg.d_model),
        "attn": attn_mod.init_attention(
            k1, cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim_),
        "mlp": init_mlp(k2, cfg.d_model, cfg.d_ff, gated=False),
    }


def _init_dec_layer(rng, cfg):
    k1, k2, k3 = jax.random.split(rng, 3)
    return {
        "norm1": init_layernorm(cfg.d_model),
        "norm_x": init_layernorm(cfg.d_model),
        "norm2": init_layernorm(cfg.d_model),
        "attn": attn_mod.init_attention(
            k1, cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim_),
        "xattn": attn_mod.init_attention(
            k2, cfg.d_model, cfg.n_heads, cfg.n_heads, cfg.head_dim_),
        "mlp": init_mlp(k3, cfg.d_model, cfg.d_ff, gated=False),
    }


def _padded_vocab(cfg: ModelConfig) -> int:
    """Vocab padded to a multiple of 128 so the TP axis divides it
    (Megatron-style; whisper's 51865 is 5·11·23·41). Padded logit columns
    are masked to -inf before softmax/argmax."""
    return ((cfg.vocab + 127) // 128) * 128


def _mask_pad_logits(cfg: ModelConfig, logits):
    v_pad = logits.shape[-1]
    if v_pad == cfg.vocab:
        return logits
    ok = jnp.arange(v_pad) < cfg.vocab
    return jnp.where(ok, logits, jnp.asarray(-2.0 ** 30, logits.dtype))


def init_params(rng, cfg: ModelConfig, max_dec_positions: int = 4096):
    ke, kd, kt, kp = jax.random.split(rng, 4)
    enc = jax.vmap(lambda r: _init_enc_layer(r, cfg))(
        jax.random.split(ke, cfg.n_enc_layers))
    dec = jax.vmap(lambda r: _init_dec_layer(r, cfg))(
        jax.random.split(kd, cfg.n_layers))
    return {
        "embed": init_embed(kt, _padded_vocab(cfg), cfg.d_model),
        "pos_embed": 0.01 * jax.random.normal(
            kp, (max_dec_positions, cfg.d_model)),
        "enc_layers": enc,
        "enc_norm": init_layernorm(cfg.d_model),
        "layers": dec,
        "final_norm": init_layernorm(cfg.d_model),
    }  # whisper ties the unembedding to the token embedding


def encode(cfg: ModelConfig, policy: Policy, params, frames):
    """frames: (B, enc_seq, d) stub embeddings → encoder states."""
    s = frames.shape[1]
    x = frames.astype(COMPUTE_DTYPE) + sinusoidal_positions(
        s, cfg.d_model).astype(COMPUTE_DTYPE)[None]
    x = policy.act_residual(x)
    positions = jnp.arange(s)[None, :]

    def body(x, p):
        h = layernorm(p["norm1"], x)
        o, _ = attn_mod.attend(
            p["attn"], h, positions, n_heads=cfg.n_heads,
            n_kv_heads=cfg.n_kv_heads, head_dim=cfg.head_dim_,
            rope_theta=cfg.rope_theta, kind="full", use_rope=False,
            policy=policy, dense_max_seq=cfg.dense_attn_max)
        x = x + o
        h = layernorm(p["norm2"], x)
        x = x + mlp(p["mlp"], h, act="gelu", policy=policy)
        return policy.act_residual(x), None

    if cfg.remat:
        body = jax.checkpoint(body)
    if cfg.use_scan:
        x, _ = jax.lax.scan(body, x, params["enc_layers"])
    else:
        for i in range(cfg.n_enc_layers):
            x, _ = body(x, jax.tree.map(lambda a: a[i],
                                        params["enc_layers"]))
    return layernorm(params["enc_norm"], x)


def _dec_block(p, cfg, policy, x, positions, enc_kv, cache, decode):
    h = layernorm(p["norm1"], x)
    if decode:
        o, cache = attn_mod.decode_attend(
            p["attn"], h, cache, positions, n_heads=cfg.n_heads,
            n_kv_heads=cfg.n_kv_heads, head_dim=cfg.head_dim_,
            rope_theta=cfg.rope_theta, window=None, use_rope=False,
            policy=policy)
    else:
        o, (k, v) = attn_mod.attend(
            p["attn"], h, positions, n_heads=cfg.n_heads,
            n_kv_heads=cfg.n_kv_heads, head_dim=cfg.head_dim_,
            rope_theta=cfg.rope_theta, kind="causal", use_rope=False,
            policy=policy, dense_max_seq=cfg.dense_attn_max,
            kv_block=cfg.kv_block)
        if cache is not None:
            cache = attn_mod.cache_from_prefill(k, v, positions,
                                                cache["k"].shape[2])
    x = x + o
    h = layernorm(p["norm_x"], x)
    x = x + attn_mod.cross_attend(
        p["xattn"], h, enc_kv, n_heads=cfg.n_heads, n_kv_heads=cfg.n_heads,
        head_dim=cfg.head_dim_, policy=policy)
    h = layernorm(p["norm2"], x)
    x = x + mlp(p["mlp"], h, act="gelu", policy=policy)
    return policy.act_residual(x), cache


def _cross_kv(cfg, params, enc_out):
    """Precompute per-layer cross-attention K/V from encoder output."""
    def one(p):
        return attn_mod.encoder_kv(p["xattn"], enc_out,
                                   n_kv_heads=cfg.n_heads,
                                   head_dim=cfg.head_dim_)
    return jax.vmap(one)(params["layers"])  # stacked (L, B, S_enc, H, Dh)


def _decoder(cfg, policy, params, x, positions, cross_kv, caches, decode):
    def body(carry, inp):
        x = carry
        p, ckv, cache = inp
        x, new_cache = _dec_block(p, cfg, policy, x, positions, ckv, cache,
                                  decode)
        return x, (new_cache if new_cache is not None else 0)

    if cfg.remat and not decode:
        body = jax.checkpoint(body)

    def scan_or_unroll(body_fn, init, xs):
        if cfg.use_scan:
            return jax.lax.scan(body_fn, init, xs)
        carry, ys = init, []
        for i in range(cfg.n_layers):
            carry, y = body_fn(carry, jax.tree.map(lambda a: a[i], xs))
            ys.append(y)
        stack = jax.tree.map(lambda *a: jnp.stack(a), *ys)
        return carry, stack

    layer_caches = None if caches is None else caches["layers"]
    if layer_caches is None:
        def body_nc(carry, inp):
            p, ckv = inp
            return body(carry, (p, ckv, None))
        x, _ = scan_or_unroll(body_nc, x, (params["layers"], cross_kv))
        return x, None
    if decode:
        # cache-in-carry (single aliased buffer) — see transformer.py
        def dec_body(carry, inp):
            x, cs, i = carry
            p, ckv = inp
            c = jax.tree.map(
                lambda a: jax.lax.dynamic_index_in_dim(a, i, 0,
                                                       keepdims=False), cs)
            x, new_c = _dec_block(p, cfg, policy, x, positions, ckv, c,
                                  True)
            cs = jax.tree.map(
                lambda a, n: jax.lax.dynamic_update_index_in_dim(
                    a, n.astype(a.dtype), i, 0), cs, new_c)
            return (x, cs, i + 1), None

        if cfg.use_scan:
            (x, new_caches, _), _ = jax.lax.scan(
                dec_body, (x, layer_caches, jnp.zeros((), jnp.int32)),
                (params["layers"], cross_kv))
        else:
            carry = (x, layer_caches, jnp.zeros((), jnp.int32))
            for i in range(cfg.n_layers):
                carry, _ = dec_body(carry, jax.tree.map(
                    lambda a: a[i], (params["layers"], cross_kv)))
            x, new_caches, _ = carry
        return x, {"layers": new_caches}
    x, new_caches = scan_or_unroll(
        body, x, (params["layers"], cross_kv, layer_caches))
    return x, {"layers": new_caches}


def _embed_dec(cfg, params, tokens, pos0=0):
    x = embed(params["embed"], tokens, COMPUTE_DTYPE)
    s = tokens.shape[1]
    pe = jax.lax.dynamic_slice_in_dim(
        params["pos_embed"], pos0, s, axis=0) if isinstance(pos0, int) else (
        params["pos_embed"][pos0])
    return x + pe.astype(COMPUTE_DTYPE)


def apply_train(cfg: ModelConfig, policy: Policy, params, tokens, frames):
    """(tokens (B,S), frames (B,enc_seq,d)) → (logits, aux=0)."""
    enc_out = encode(cfg, policy, params, frames)
    cross_kv = _cross_kv(cfg, params, enc_out)
    x = policy.act_residual(_embed_dec(cfg, params, tokens))
    positions = jnp.arange(tokens.shape[1])[None, :]
    x, _ = _decoder(cfg, policy, params, x, positions, cross_kv, None, False)
    x = layernorm(params["final_norm"], x)
    logits = _mask_pad_logits(cfg, x @ params["embed"]["tokens"].astype(x.dtype).T)
    return logits.astype(jnp.float32), jnp.zeros((), jnp.float32)


def init_dec_cache(cfg: ModelConfig, batch: int, cache_len: int, enc_seq: int):
    self_c = attn_mod.init_cache(batch, cache_len, cfg.n_kv_heads,
                                 cfg.head_dim_)
    cross = {
        "k": jnp.zeros((batch, enc_seq, cfg.n_heads, cfg.head_dim_),
                       COMPUTE_DTYPE),
        "v": jnp.zeros((batch, enc_seq, cfg.n_heads, cfg.head_dim_),
                       COMPUTE_DTYPE),
    }
    def stack(x):
        return jnp.broadcast_to(x, (cfg.n_layers,) + x.shape).copy()
    return {"layers": jax.tree.map(stack, self_c),
            "cross": jax.tree.map(stack, cross)}


def prefill(cfg: ModelConfig, policy: Policy, params, tokens, frames,
            cache_len):
    enc_out = encode(cfg, policy, params, frames)
    ckv = _cross_kv(cfg, params, enc_out)
    caches = init_dec_cache(cfg, tokens.shape[0], cache_len, frames.shape[1])
    x = policy.act_residual(_embed_dec(cfg, params, tokens))
    positions = jnp.arange(tokens.shape[1])[None, :]
    x, new = _decoder(cfg, policy, params, x, positions,
                      (ckv[0], ckv[1]), caches, False)
    caches = {"layers": new["layers"],
              "cross": {"k": ckv[0].astype(COMPUTE_DTYPE),
                        "v": ckv[1].astype(COMPUTE_DTYPE)}}
    x = layernorm(params["final_norm"], x[:, -1:])
    logits = _mask_pad_logits(cfg, x @ params["embed"]["tokens"].astype(x.dtype).T)
    return logits[:, 0].astype(jnp.float32), caches


def decode_step(cfg: ModelConfig, policy: Policy, params, token, caches, pos):
    x = embed(params["embed"], token, COMPUTE_DTYPE)
    x = x + params["pos_embed"][pos][:, None].astype(COMPUTE_DTYPE)
    positions = pos[:, None]
    cross = (caches["cross"]["k"], caches["cross"]["v"])
    x, new = _decoder(cfg, policy, params, x, positions, cross,
                      {"layers": caches["layers"]}, True)
    caches = {"layers": new["layers"], "cross": caches["cross"]}
    x = layernorm(params["final_norm"], x)
    logits = _mask_pad_logits(cfg, x @ params["embed"]["tokens"].astype(x.dtype).T)
    return logits[:, 0].astype(jnp.float32), caches
