"""GPipe-style pipeline parallelism via shard_map + ppermute.

Alternative schedule for very deep stacks (e.g. qwen2-72b 80L): stages are
laid out along a mesh axis; microbatch activations rotate stage-to-stage
with ``collective_permute`` while every stage computes — the classic
bubble-bounded schedule (bubble fraction = (S-1)/(M+S-1)).

``gpipe_apply`` is schedule-exact and correctness-tested against the
sequential stack (tests/test_sharding.py); the LM integration point is
``stage_fn = one scan-group of blocks`` with stage-stacked params.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.sharding import shard_map_compat


def gpipe_apply(stage_fn, stage_params, x_micro, *, mesh, axis: str):
    """Run ``n_stages = mesh[axis]`` pipeline stages over microbatches.

    stage_fn: (params_of_one_stage, x (mb, …)) → (mb, …); same out shape
    stage_params: pytree with leading stage dim == n_stages (sharded on axis)
    x_micro: (n_micro, mb, …) inputs (replicated along ``axis``)
    Returns (n_micro, mb, …) outputs of the final stage (replicated).
    """
    n_stages = dict(zip(mesh.axis_names, mesh.devices.shape))[axis]
    n_micro = x_micro.shape[0]
    ticks = n_micro + n_stages - 1
    ring = [(i, (i + 1) % n_stages) for i in range(n_stages)]

    def body(params, xs):
        params = jax.tree.map(lambda a: a[0], params)  # my stage's slice
        sid = jax.lax.axis_index(axis)
        zero = jnp.zeros_like(xs[0])

        def tick(t, carry):
            buf, out = carry            # buf: activation entering my stage
            mb_idx = jnp.clip(t, 0, n_micro - 1)
            x_in = jnp.where(sid == 0,
                             jax.lax.dynamic_index_in_dim(
                                 xs, mb_idx, 0, keepdims=False),
                             buf)
            active = (t - sid >= 0) & (t - sid < n_micro)
            y = jnp.where(active, stage_fn(params, x_in), zero)
            # the last stage emits microbatch (t - S + 1)
            emit = t - (n_stages - 1)
            do_emit = (sid == n_stages - 1) & (emit >= 0)
            out = jax.lax.cond(
                do_emit,
                lambda o: jax.lax.dynamic_update_index_in_dim(
                    o, y, jnp.clip(emit, 0, n_micro - 1), 0),
                lambda o: o, out)
            buf = jax.lax.ppermute(y, axis, ring)  # stage s → s+1
            return buf, out

        _, out = jax.lax.fori_loop(
            0, ticks, tick, (zero, jnp.zeros_like(xs)))
        # outputs live on the last stage only (zeros elsewhere): share them
        return jax.lax.psum(out, axis)

    return shard_map_compat(
        body, mesh=mesh,
        in_specs=(P(axis), P()),
        out_specs=P(),
    )(stage_params, x_micro)
