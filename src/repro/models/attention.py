"""Attention: GQA with rope/qk-norm/bias, causal/sliding-window/full masks,
dense + blockwise(flash-scan) paths, KV caches, and distributed flash-decode
(partial-softmax combine over the seq-sharded ``model`` axis).

Cache layout (per layer): {"k": (B, S, Hkv, Dh), "v": same, "pos": (B, S)}
``pos`` is the absolute position stored in each slot (-1 = empty). Sliding
windows use rolling-buffer caches of size min(window, seq) (vLLM-style).
K is stored post-rope.
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.common import apply_rope, dense_init, rmsnorm
from repro.sharding import Policy, current_mesh, shard_map_compat

NEG_INF = -2.0 ** 30  # large-but-finite: keeps masked softmax NaN-free


def init_attention(rng, d_model, n_heads, n_kv_heads, head_dim, *,
                   qkv_bias=False, qk_norm=False, dtype=jnp.float32):
    ks = jax.random.split(rng, 4)
    p = {
        "wq": dense_init(ks[0], d_model, n_heads * head_dim, dtype),
        "wk": dense_init(ks[1], d_model, n_kv_heads * head_dim, dtype),
        "wv": dense_init(ks[2], d_model, n_kv_heads * head_dim, dtype),
        "wo": dense_init(ks[3], n_heads * head_dim, d_model, dtype),
    }
    if qkv_bias:
        p["wq_bias"] = jnp.zeros((n_heads * head_dim,), dtype)
        p["wk_bias"] = jnp.zeros((n_kv_heads * head_dim,), dtype)
        p["wv_bias"] = jnp.zeros((n_kv_heads * head_dim,), dtype)
    if qk_norm:
        p["q_norm"] = {"scale": jnp.ones((head_dim,), jnp.float32)}
        p["k_norm"] = {"scale": jnp.ones((head_dim,), jnp.float32)}
    return p


def _project_qkv(p, x, n_heads, n_kv_heads, head_dim, positions, theta,
                 use_rope=True):
    b, s, _ = x.shape
    q = x @ p["wq"].astype(x.dtype)
    k = x @ p["wk"].astype(x.dtype)
    v = x @ p["wv"].astype(x.dtype)
    if "wq_bias" in p:
        q = q + p["wq_bias"].astype(x.dtype)
        k = k + p["wk_bias"].astype(x.dtype)
        v = v + p["wv_bias"].astype(x.dtype)
    q = q.reshape(b, s, n_heads, head_dim)
    k = k.reshape(b, s, n_kv_heads, head_dim)
    v = v.reshape(b, s, n_kv_heads, head_dim)
    if "q_norm" in p:  # qwen3-style per-head rms norm
        q = rmsnorm(p["q_norm"], q)
        k = rmsnorm(p["k_norm"], k)
    if use_rope:
        q = apply_rope(q, positions, theta)
        k = apply_rope(k, positions, theta)
    return q, k, v


def _mask(q_pos, k_pos, kind, window):
    """q_pos: (…, Sq), k_pos: (…, Sk) → bool (…, Sq, Sk) allowed."""
    dq = q_pos[..., :, None]
    dk = k_pos[..., None, :]
    ok = dk >= 0
    if kind == "causal":
        ok &= dk <= dq
        if window is not None:
            ok &= dk > dq - window
    elif kind == "full":
        pass
    else:
        raise ValueError(kind)
    return ok


def _repeat_kv(k, g):
    """(B,S,Hkv,Dh) → (B,S,H,Dh). Materialising the GQA repeat keeps every
    attention operand sharded H-ways on ``model`` — without it GSPMD mixes
    (Hkv, G) factorizations and falls back to full rematerialization
    (observed in dry-run iteration 0; see EXPERIMENTS.md §Perf)."""
    if g == 1:
        return k
    b, s, hkv, dh = k.shape
    return jnp.repeat(k, g, axis=2)


def _sdpa(q, k, v, mask, scale):
    """Dense attention. q: (B,Sq,H,Dh), k/v: (B,Sk,Hkv,Dh), mask (B,Sq,Sk)."""
    b, sq, h, dh = q.shape
    g = h // k.shape[2]
    k = _repeat_kv(k, g)
    v = _repeat_kv(v, g)
    scores = jnp.einsum("bqhd,bshd->bhqs", q, k) * scale   # (B,H,Sq,Sk)
    scores = jnp.where(mask[:, None], scores.astype(jnp.float32), NEG_INF)
    w = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = jnp.einsum("bhqs,bshd->bqhd", w, v)
    return out


def _blockwise_sdpa(q, k, v, q_pos, k_pos, kind, window, scale, kv_block=512):
    """Flash-style attention: lax.scan over KV blocks with running
    (max, denom, acc) — O(S·kv_block) live memory instead of O(S²).

    Per-iteration cost is constant (full Q vs one KV block, masked), so the
    roofline harness treats the KV loop as a 'chunks' scale dim.
    """
    b, sq, h, dh = q.shape
    g = h // k.shape[2]
    k = _repeat_kv(k, g)
    v = _repeat_kv(v, g)
    sk = k.shape[1]
    pad = (-sk) % kv_block
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k_pos = jnp.pad(k_pos, ((0, 0), (0, pad)), constant_values=-1)
    nb = k.shape[1] // kv_block
    kb = k.reshape(b, nb, kv_block, h, dh).swapaxes(0, 1)
    vb = v.reshape(b, nb, kv_block, h, dh).swapaxes(0, 1)
    pb = k_pos.reshape(b, nb, kv_block).swapaxes(0, 1)

    acc0 = jnp.zeros((b, sq, h, dh), jnp.float32)
    m0 = jnp.full((b, h, sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, h, sq), jnp.float32)

    def body(carry, blk):
        acc, m, l = carry
        kc, vc, pc = blk
        s = jnp.einsum("bqhd,bshd->bhqs", q, kc).astype(jnp.float32) * scale
        ok = _mask(q_pos, pc, kind, window)               # (B, Sq, blk)
        s = jnp.where(ok[:, None], s, NEG_INF)
        m_new = jnp.maximum(m, s.max(-1))
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(s - m_new[..., None])
        l_new = l * alpha + p.sum(-1)
        acc_new = acc * alpha.transpose(0, 2, 1)[..., None] + jnp.einsum(
            "bhqs,bshd->bqhd", p.astype(q.dtype), vc
        ).astype(jnp.float32)
        return (acc_new, m_new, l_new), None

    (acc, m, l), _ = jax.lax.scan(body, (acc0, m0, l0), (kb, vb, pb))
    out = acc / jnp.maximum(l, 1e-30).transpose(0, 2, 1)[..., None]
    return out.astype(q.dtype)


def attend(p, x, positions, *, n_heads, n_kv_heads, head_dim, rope_theta,
           kind="causal", window=None, use_rope=True, policy: Policy,
           dense_max_seq=8192, kv_block=512):
    """Full-sequence attention (training / prefill compute). x: (B,S,D)."""
    q, k, v = _project_qkv(p, x, n_heads, n_kv_heads, head_dim, positions,
                           rope_theta, use_rope)
    q = policy.act_heads(q)
    k = policy.act_heads(k)
    v = policy.act_heads(v)
    scale = head_dim ** -0.5
    pos2 = jnp.broadcast_to(positions if positions.ndim == 2
                            else positions[None], x.shape[:2])
    if x.shape[1] <= dense_max_seq:
        mask = _mask(pos2, pos2, kind, window)
        out = _sdpa(q, k, v, mask, scale)
    else:
        out = _blockwise_sdpa(q, k, v, pos2, pos2, kind, window, scale,
                              kv_block)
    out = out.reshape(*x.shape[:2], n_heads * head_dim)
    y = out @ p["wo"].astype(x.dtype)
    return y, (k, v)


def cross_attend(p, x, enc_kv, *, n_heads, n_kv_heads, head_dim,
                 policy: Policy):
    """Cross-attention to precomputed encoder K/V (whisper decoder)."""
    b, s, _ = x.shape
    q = (x @ p["wq"].astype(x.dtype)).reshape(b, s, n_heads, head_dim)
    k, v = enc_kv
    scale = head_dim ** -0.5
    mask = jnp.ones((b, s, k.shape[1]), bool)
    out = _sdpa(q, k, v, mask, scale).reshape(b, s, n_heads * head_dim)
    return out @ p["wo"].astype(x.dtype)


def encoder_kv(p, enc_out, *, n_kv_heads, head_dim):
    b, s, _ = enc_out.shape
    k = (enc_out @ p["wk"].astype(enc_out.dtype)).reshape(b, s, n_kv_heads, head_dim)
    v = (enc_out @ p["wv"].astype(enc_out.dtype)).reshape(b, s, n_kv_heads, head_dim)
    return k, v


# ---------------------------------------------------------------------------
# KV caches
# ---------------------------------------------------------------------------


def init_cache(batch, cache_len, n_kv_heads, head_dim, dtype=jnp.bfloat16):
    """Decode cache layout (B, Hkv, S, Dh): S-major-last-two matches the
    flash-decode dot layout, so no per-step cache transpose (a full cache
    copy per layer otherwise — §Perf hillclimb A)."""
    return {
        "k": jnp.zeros((batch, n_kv_heads, cache_len, head_dim), dtype),
        "v": jnp.zeros((batch, n_kv_heads, cache_len, head_dim), dtype),
        "pos": jnp.full((batch, cache_len), -1, jnp.int32),
    }


def cache_from_prefill(k, v, positions, cache_len):
    """Keep the trailing ``cache_len`` positions (rolling buffer for SWA).
    k, v: (B, S, Hkv, Dh) from the prefill pass → (B, Hkv, S', Dh) cache."""
    s = k.shape[1]
    kt = k.swapaxes(1, 2)                                 # (B, Hkv, S, Dh)
    vt = v.swapaxes(1, 2)
    pos2 = jnp.broadcast_to(positions if positions.ndim == 2
                            else positions[None], k.shape[:2])
    if s <= cache_len:
        pad = cache_len - s
        return {
            "k": jnp.pad(kt, ((0, 0), (0, 0), (0, pad), (0, 0))),
            "v": jnp.pad(vt, ((0, 0), (0, 0), (0, pad), (0, 0))),
            "pos": jnp.pad(pos2.astype(jnp.int32), ((0, 0), (0, pad)),
                           constant_values=-1),
        }
    # rolling placement: absolute position t lives in slot t % cache_len
    keep = jnp.arange(s - cache_len, s)
    slots = keep % cache_len
    b = k.shape[0]
    out = init_cache(b, cache_len, k.shape[2], k.shape[3], k.dtype)
    out["k"] = out["k"].at[:, :, slots].set(kt[:, :, keep])
    out["v"] = out["v"].at[:, :, slots].set(vt[:, :, keep])
    out["pos"] = out["pos"].at[:, slots].set(pos2[:, keep].astype(jnp.int32))
    return out


def _decode_attend_local(q, cache_k, cache_v, cache_pos, pos, scale):
    """Single-token attention vs a (local shard of a) cache.

    Returns un-normalised (acc, m, l) so shards can be combined
    (flash-decode partial-softmax algebra).
    q: (B, H, Dh); cache: (B, Hkv, S, Dh); pos: (B,) current position.
    fp32 accumulation via preferred_element_type — upcasting operands
    would materialise an f32 copy of the cache (§Perf hillclimb A).
    """
    b, h, dh = q.shape
    hkv = cache_k.shape[1]
    g = h // hkv
    qg = q.reshape(b, hkv, g, dh)
    s = jnp.einsum("bkgd,bksd->bkgs", qg, cache_k,
                   preferred_element_type=jnp.float32) * scale
    ok = (cache_pos >= 0) & (cache_pos <= pos[:, None])   # (B, S)
    s = jnp.where(ok[:, None, None], s, NEG_INF)
    m = s.max(-1)                                         # (B,Hkv,G)
    p = jnp.exp(s - m[..., None])
    l = p.sum(-1)
    acc = jnp.einsum("bkgs,bksd->bkgd", p.astype(cache_v.dtype), cache_v,
                     preferred_element_type=jnp.float32)
    return acc, m, l


def decode_attend(p, x, cache, pos, *, n_heads, n_kv_heads, head_dim,
                  rope_theta, window, use_rope=True, policy: Policy):
    """One-token decode: x (B, 1, D), cache seq-sharded over ``model``.

    With an active mesh, runs the partial-softmax combine as a shard_map
    over the model axis (each shard scores its cache slice; softmax stats
    are merged with the flash-decode rescaling identity). Mathematically
    exact — tests pin it against the dense path.
    """
    b = x.shape[0]
    positions = pos[:, None] if pos.ndim == 1 else pos
    q, k_new, v_new = _project_qkv(p, x, n_heads, n_kv_heads, head_dim,
                                   positions, rope_theta, use_rope)
    q = q[:, 0]                                           # (B, H, Dh)
    cache_len = cache["k"].shape[2]                       # (B, Hkv, S, Dh)
    scale = head_dim ** -0.5
    pos_b = positions[:, 0]

    if policy.active and policy.model_axis is not None:
        # Fused update+attend shard_map over the seq-sharded cache. The
        # scatter is SHARD-LOCAL (each seq shard masks whether the slot
        # lands in its slice): a global `.at[b, slot].set` on a sharded
        # dim made GSPMD reshard the whole cache every layer — measured
        # 3.97 GB bytes + 490 MB collectives per layer on qwen2-72b
        # decode_32k vs ~75 MB of cache physics (§Perf hillclimb A).
        mesh = current_mesh()
        axis = policy.model_axis
        bb = policy.b

        def shard_fn(q_, kn, vn, ck, cv, cp, pb):
            s_local = ck.shape[2]
            start = jax.lax.axis_index(axis) * s_local
            slot = (pb % cache_len).astype(jnp.int32) - start  # (B,)
            mine = (slot >= 0) & (slot < s_local)
            slot_safe = jnp.clip(slot, 0, s_local - 1)
            nb, nh = ck.shape[0], ck.shape[1]
            bidx = jnp.arange(nb)[:, None]
            hidx = jnp.arange(nh)[None, :]
            sidx = slot_safe[:, None]
            ck = ck.at[bidx, hidx, sidx].set(
                jnp.where(mine[:, None, None], kn.astype(ck.dtype),
                          ck[bidx, hidx, sidx]))
            cv = cv.at[bidx, hidx, sidx].set(
                jnp.where(mine[:, None, None], vn.astype(cv.dtype),
                          cv[bidx, hidx, sidx]))
            cp = cp.at[jnp.arange(nb), slot_safe].set(
                jnp.where(mine, pb.astype(jnp.int32),
                          cp[jnp.arange(nb), slot_safe]))
            cpos = cp
            if window is not None:
                cpos = jnp.where(cp > (pb[:, None] - window), cp, -1)
            acc, m, l = _decode_attend_local(q_, ck, cv, cpos, pb, scale)
            m_g = jax.lax.pmax(m, axis)
            corr = jnp.exp(m - m_g)
            l_g = jax.lax.psum(l * corr, axis)
            acc_g = jax.lax.psum(acc * corr[..., None], axis)
            out = acc_g / jnp.maximum(l_g, 1e-30)[..., None]
            return out, ck, cv, cp

        out, new_k, new_v, new_p = shard_map_compat(
            shard_fn,
            mesh=mesh,
            in_specs=(P(bb, None, None),
                      P(bb, None, None), P(bb, None, None),
                      P(bb, None, axis, None),
                      P(bb, None, axis, None),
                      P(bb, axis),
                      P(bb)),
            out_specs=(P(bb, None, None, None),
                       P(bb, None, axis, None),
                       P(bb, None, axis, None),
                       P(bb, axis)),
        )(q, k_new[:, 0], v_new[:, 0], cache["k"], cache["v"],
          cache["pos"], pos_b)
        cache = {"k": new_k, "v": new_v, "pos": new_p}
    else:
        slot = (pos_b % cache_len).astype(jnp.int32)
        nb, nh = cache["k"].shape[0], cache["k"].shape[1]
        bidx = jnp.arange(nb)[:, None]
        hidx = jnp.arange(nh)[None, :]
        sidx = slot[:, None]
        cache = {
            "k": cache["k"].at[bidx, hidx, sidx].set(
                k_new[:, 0].astype(cache["k"].dtype)),
            "v": cache["v"].at[bidx, hidx, sidx].set(
                v_new[:, 0].astype(cache["v"].dtype)),
            "pos": cache["pos"].at[jnp.arange(nb), slot].set(
                pos_b.astype(jnp.int32)),
        }
        if window is not None:
            cpos = jnp.where(cache["pos"] > (pos_b[:, None] - window),
                             cache["pos"], -1)
        else:
            cpos = cache["pos"]
        acc, m, l = _decode_attend_local(q, cache["k"], cache["v"], cpos,
                                         pos_b, scale)
        out = acc / jnp.maximum(l, 1e-30)[..., None]

    out = out.reshape(b, 1, n_heads * head_dim).astype(x.dtype)
    y = out @ p["wo"].astype(x.dtype)
    return y, cache
