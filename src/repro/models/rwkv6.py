"""RWKV-6 "Finch" (arXiv:2404.05892): data-dependent decay, attention-free.

TimeMix with DDLERP token-shift mixing + LoRA-modulated per-channel decay,
matrix-state recurrence (models/recurrence.py chunked engine), grouped
per-head output norm; ChannelMix with squared-relu. LayerNorms as in the
reference implementation.

Decode state per layer: {"tm_shift": (B,d), "cm_shift": (B,d),
"wkv": (B,H,Dk,Dv)} — O(d + H·Dk·Dv) per token, no KV cache; this is why
rwkv6 is a ``long_500k`` architecture.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import dense_init, init_layernorm, layernorm
from repro.models.recurrence import (
    chunked_matrix_recurrence,
    matrix_recurrence_step,
)
from repro.sharding import Policy

LORA_R = 64
DDLERP_R = 32


def init_timemix(rng, d, n_heads, head_dim, dtype=jnp.float32):
    ks = jax.random.split(rng, 12)
    u = 0.5 * jax.random.uniform(ks[0], (n_heads, head_dim))
    return {
        "mu_x": jnp.zeros((d,), jnp.float32),
        "mu": jnp.zeros((5, d), jnp.float32),            # w,k,v,r,g bases
        "ddlerp_a": dense_init(ks[1], d, 5 * DDLERP_R, dtype),
        "ddlerp_b": 0.01 * jax.random.normal(ks[2], (5, DDLERP_R, d), dtype),
        "w0": jnp.tile(jnp.linspace(-6.0, -1.0, head_dim), n_heads),
        "lora_w_a": dense_init(ks[3], d, LORA_R, dtype),
        "lora_w_b": 0.01 * jax.random.normal(ks[4], (LORA_R, d), dtype),
        "u": u,                                           # per-head bonus
        "w_r": dense_init(ks[5], d, d, dtype),
        "w_k": dense_init(ks[6], d, d, dtype),
        "w_v": dense_init(ks[7], d, d, dtype),
        "w_g": dense_init(ks[8], d, d, dtype),
        "w_o": dense_init(ks[9], d, d, dtype),
        "out_norm": {"scale": jnp.ones((n_heads, head_dim), jnp.float32),
                     "bias": jnp.zeros((n_heads, head_dim), jnp.float32)},
    }


def init_channelmix(rng, d, d_ff, dtype=jnp.float32):
    ks = jax.random.split(rng, 3)
    return {
        "mu_k": jnp.zeros((d,), jnp.float32),
        "mu_r": jnp.zeros((d,), jnp.float32),
        "w_k": dense_init(ks[0], d, d_ff, dtype),
        "w_v": dense_init(ks[1], d_ff, d, dtype),
        "w_r": dense_init(ks[2], d, d, dtype),
    }


def init_rwkv_block(rng, d, d_ff, n_heads, head_dim, dtype=jnp.float32):
    k1, k2 = jax.random.split(rng)
    return {
        "ln1": init_layernorm(d),
        "ln2": init_layernorm(d),
        "rwkv": {"tm": init_timemix(k1, d, n_heads, head_dim, dtype),
                 "cm": init_channelmix(k2, d, d_ff, dtype)},
    }


def _group_norm(p, x):
    """Per-head layernorm of (…, H, Dh)."""
    mu = x.mean(-1, keepdims=True)
    var = x.var(-1, keepdims=True)
    xhat = (x - mu) * jax.lax.rsqrt(var + 1e-5)
    return xhat * p["scale"] + p["bias"]


def _ddlerp(p, x, xx):
    """Data-dependent lerp producing the 5 mixed inputs (w,k,v,r,g)."""
    base = x + xx * p["mu_x"].astype(x.dtype)
    lo = jnp.tanh(base @ p["ddlerp_a"].astype(x.dtype))
    lo = lo.reshape(*x.shape[:-1], 5, DDLERP_R)
    adj = jnp.einsum("...fr,frd->...fd", lo, p["ddlerp_b"].astype(x.dtype))
    mixed = x[..., None, :] + xx[..., None, :] * (
        p["mu"].astype(x.dtype) + adj)
    return [mixed[..., i, :] for i in range(5)]           # each (…, d)


def _decay(p, xw, n_heads, head_dim):
    """Per-channel data-dependent decay w_t ∈ (0,1)."""
    lo = jnp.tanh(xw @ p["lora_w_a"].astype(xw.dtype)) @ p["lora_w_b"].astype(xw.dtype)
    wlog = p["w0"].astype(jnp.float32) + lo.astype(jnp.float32)
    w = jnp.exp(-jnp.exp(wlog))
    return w.reshape(*xw.shape[:-1], n_heads, head_dim)


def timemix_seq(p, x, shift_in, s0, *, n_heads, head_dim, chunk, policy,
                unroll=False):
    """x: (B, T, d). shift_in: (B, d) last token of previous segment.
    Returns (out, (last_x, sT))."""
    b, t, d = x.shape
    prev = jnp.concatenate([shift_in[:, None], x[:, :-1]], axis=1)
    xx = prev - x
    xw, xk, xv, xr, xg = _ddlerp(p, x, xx)
    r = (xr @ p["w_r"].astype(x.dtype)).reshape(b, t, n_heads, head_dim)
    k = (xk @ p["w_k"].astype(x.dtype)).reshape(b, t, n_heads, head_dim)
    v = (xv @ p["w_v"].astype(x.dtype)).reshape(b, t, n_heads, head_dim)
    g = xg @ p["w_g"].astype(x.dtype)
    w = _decay(p, xw, n_heads, head_dim)                  # (B,T,H,Dh) fp32
    tbhd = lambda z: z.swapaxes(0, 1)                     # (T,B,H,Dh)
    o, sT = chunked_matrix_recurrence(
        tbhd(r), tbhd(k), tbhd(v), tbhd(w), p["u"], s0, chunk=chunk,
        unroll=unroll)
    o = o.swapaxes(0, 1)                                  # (B,T,H,Dh)
    o = _group_norm(p["out_norm"], o.astype(jnp.float32)).astype(x.dtype)
    o = (o.reshape(b, t, d) * jax.nn.silu(g))
    out = o @ p["w_o"].astype(x.dtype)
    return out, (x[:, -1], sT)


def timemix_step(p, x, shift_in, s, *, n_heads, head_dim):
    """Single-token decode. x: (B, d)."""
    b, d = x.shape
    xx = shift_in - x
    xw, xk, xv, xr, xg = _ddlerp(p, x, xx)
    r = (xr @ p["w_r"].astype(x.dtype)).reshape(b, n_heads, head_dim)
    k = (xk @ p["w_k"].astype(x.dtype)).reshape(b, n_heads, head_dim)
    v = (xv @ p["w_v"].astype(x.dtype)).reshape(b, n_heads, head_dim)
    g = xg @ p["w_g"].astype(x.dtype)
    w = _decay(p, xw, n_heads, head_dim)
    o, sT = matrix_recurrence_step(r, k, v, w, p["u"], s)
    o = _group_norm(p["out_norm"], o.astype(jnp.float32)).astype(x.dtype)
    out = (o.reshape(b, d) * jax.nn.silu(g)) @ p["w_o"].astype(x.dtype)
    return out, (x, sT)


def channelmix_seq(p, x, shift_in):
    prev = jnp.concatenate([shift_in[:, None], x[:, :-1]], axis=1)
    xx = prev - x
    xk = x + xx * p["mu_k"].astype(x.dtype)
    xr = x + xx * p["mu_r"].astype(x.dtype)
    k = jnp.square(jax.nn.relu(xk @ p["w_k"].astype(x.dtype)))
    kv = k @ p["w_v"].astype(x.dtype)
    return jax.nn.sigmoid(xr @ p["w_r"].astype(x.dtype)) * kv, x[:, -1]


def channelmix_step(p, x, shift_in):
    xx = shift_in - x
    xk = x + xx * p["mu_k"].astype(x.dtype)
    xr = x + xx * p["mu_r"].astype(x.dtype)
    k = jnp.square(jax.nn.relu(xk @ p["w_k"].astype(x.dtype)))
    kv = k @ p["w_v"].astype(x.dtype)
    return jax.nn.sigmoid(xr @ p["w_r"].astype(x.dtype)) * kv, x


def rwkv_block_seq(p, x, state, *, n_heads, head_dim, chunk,
                   policy: Policy, unroll=False):
    """state: {"tm_shift", "cm_shift", "wkv"}; x: (B, T, d)."""
    h = layernorm(p["ln1"], x)
    o, (tm_shift, wkv) = timemix_seq(
        p["rwkv"]["tm"], h, state["tm_shift"], state["wkv"],
        n_heads=n_heads, head_dim=head_dim, chunk=chunk, policy=policy,
        unroll=unroll)
    x = x + o
    h = layernorm(p["ln2"], x)
    o, cm_shift = channelmix_seq(p["rwkv"]["cm"], h, state["cm_shift"])
    x = x + o
    return x, {"tm_shift": tm_shift, "cm_shift": cm_shift, "wkv": wkv}


def rwkv_block_step(p, x, state, *, n_heads, head_dim, policy: Policy):
    """x: (B, d) single token."""
    h = layernorm(p["ln1"], x)
    o, (tm_shift, wkv) = timemix_step(
        p["rwkv"]["tm"], h, state["tm_shift"], state["wkv"],
        n_heads=n_heads, head_dim=head_dim)
    x = x + o
    h = layernorm(p["ln2"], x)
    o, cm_shift = channelmix_step(p["rwkv"]["cm"], h, state["cm_shift"])
    x = x + o
    return x, {"tm_shift": tm_shift, "cm_shift": cm_shift, "wkv": wkv}


def init_rwkv_state(batch, d, n_heads, head_dim, dtype=jnp.bfloat16):
    return {
        "tm_shift": jnp.zeros((batch, d), dtype),
        "cm_shift": jnp.zeros((batch, d), dtype),
        "wkv": jnp.zeros((batch, n_heads, head_dim, head_dim), jnp.float32),
    }
