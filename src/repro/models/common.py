"""Shared neural layers: norms, rope, embeddings, initializers.

Pure-JAX module style: each layer is an ``init_*`` returning a params dict
and a paired ``apply`` function. Params are nested dicts (pytrees); layer
stacks store params with a leading (L, …) dim consumed by ``lax.scan``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def truncated_normal(rng, shape, std, dtype=jnp.float32):
    return std * jax.random.truncated_normal(rng, -2.0, 2.0, shape, dtype)


def dense_init(rng, d_in, d_out, dtype=jnp.float32):
    """Fan-in scaled init (matches common LM practice)."""
    return truncated_normal(rng, (d_in, d_out), d_in ** -0.5, dtype)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------


def init_rmsnorm(d):
    return {"scale": jnp.ones((d,), jnp.float32)}


def rmsnorm(p, x, eps=1e-5):
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    out = x * jax.lax.rsqrt(var + eps) * p["scale"]
    return out.astype(dtype)


def init_layernorm(d):
    return {"scale": jnp.ones((d,), jnp.float32),
            "bias": jnp.zeros((d,), jnp.float32)}


def layernorm(p, x, eps=1e-5):
    dtype = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    out = (x - mu) * jax.lax.rsqrt(var + eps) * p["scale"] + p["bias"]
    return out.astype(dtype)


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------


def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (B, S, H, Dh); positions: (B, S) or (S,). Rotates pairs (even, odd
    halves convention — matches llama/qwen)."""
    dh = x.shape[-1]
    freqs = rope_freqs(dh, theta)                        # (Dh/2,)
    if positions.ndim == 1:
        positions = positions[None, :]
    angles = positions[..., None].astype(jnp.float32) * freqs  # (B, S, Dh/2)
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_positions(n_pos: int, d: int) -> jax.Array:
    """Whisper-style sinusoidal table (n_pos, d)."""
    half = d // 2
    log_timescale = jnp.log(10000.0) / (half - 1)
    inv = jnp.exp(-log_timescale * jnp.arange(half, dtype=jnp.float32))
    scaled = jnp.arange(n_pos, dtype=jnp.float32)[:, None] * inv[None, :]
    return jnp.concatenate([jnp.sin(scaled), jnp.cos(scaled)], axis=1)


# ---------------------------------------------------------------------------
# Embedding / unembedding
# ---------------------------------------------------------------------------


def init_embed(rng, vocab, d, dtype=jnp.float32):
    return {"tokens": truncated_normal(rng, (vocab, d), 1.0, dtype)}


def embed(p, tokens, compute_dtype=jnp.bfloat16):
    return p["tokens"].astype(compute_dtype)[tokens]


def unembed(p_embed, lm_head, x):
    """Logits; tied embeddings when lm_head is None."""
    if lm_head is None:
        w = p_embed["tokens"].astype(x.dtype).T
    else:
        w = lm_head.astype(x.dtype)
    return x @ w


def activation(name: str):
    return {
        "silu": jax.nn.silu,
        "gelu": jax.nn.gelu,
        "relu2": lambda x: jnp.square(jax.nn.relu(x)),  # nemotron/minitron
    }[name]
