"""Uniform model facade: one API across all families + input specs.

  build(cfg)  → Model with init/apply_train/prefill/decode_step/init_cache
  input_specs(cfg, shape, for_lowering) → kwargs of ShapeDtypeStructs (or
  zeros) for the requested shape cell — the dry-run's no-allocation inputs.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeSpec
from repro.models import transformer, whisper
from repro.sharding import Policy

COMPUTE_DTYPE = jnp.bfloat16


@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ModelConfig
    init: Callable
    apply_train: Callable   # (policy, params, **batch) -> (logits, aux)
    prefill: Callable       # (policy, params, cache_len, **batch) -> (logits, cache)
    decode_step: Callable   # (policy, params, token, caches, pos) -> (logits, cache)
    init_cache: Callable    # (batch, cache_len) -> cache pytree


def build(cfg: ModelConfig) -> Model:
    if cfg.family == "encdec":
        def init(rng, max_dec_positions=4096):
            return whisper.init_params(rng, cfg, max_dec_positions)

        def apply_train(policy, params, *, tokens, frames):
            return whisper.apply_train(cfg, policy, params, tokens, frames)

        def prefill_fn(policy, params, cache_len, *, tokens, frames):
            return whisper.prefill(cfg, policy, params, tokens, frames,
                                   cache_len)

        def decode_fn(policy, params, token, caches, pos):
            return whisper.decode_step(cfg, policy, params, token, caches,
                                       pos)

        def init_cache(batch, cache_len):
            return whisper.init_dec_cache(cfg, batch, cache_len, cfg.enc_seq)

        return Model(cfg, init, apply_train, prefill_fn, decode_fn,
                     init_cache)

    def init(rng):
        return transformer.init_params(rng, cfg)

    def apply_train(policy, params, *, tokens, vision_embeds=None):
        return transformer.apply_train(cfg, policy, params, tokens,
                                       vision_embeds)

    def prefill_fn(policy, params, cache_len, *, tokens, vision_embeds=None):
        return transformer.prefill(cfg, policy, params, tokens, cache_len,
                                   vision_embeds)

    def decode_fn(policy, params, token, caches, pos):
        return transformer.decode_step(cfg, policy, params, token, caches,
                                       pos)

    def init_cache(batch, cache_len):
        return transformer.init_cache(cfg, batch, cache_len)

    return Model(cfg, init, apply_train, prefill_fn, decode_fn, init_cache)


# ---------------------------------------------------------------------------
# Input specs per shape cell (dry-run stand-ins; no device allocation)
# ---------------------------------------------------------------------------


def input_specs(cfg: ModelConfig, shape: ShapeSpec, *, concrete: bool = False,
                batch_override: Optional[int] = None,
                seq_override: Optional[int] = None) -> dict[str, Any]:
    """Model inputs for one cell, as ShapeDtypeStructs (or zeros if
    ``concrete`` — used by smoke tests at reduced sizes).

    train/prefill: full-sequence inputs (+labels for train).
    decode: single token + positions; the CACHE spec comes from
    ``cache_specs`` below.
    """
    b = batch_override or shape.global_batch
    s = seq_override or shape.seq_len

    def arr(shp, dtype):
        if concrete:
            return jnp.zeros(shp, dtype)
        return jax.ShapeDtypeStruct(shp, dtype)

    if shape.kind in ("train", "prefill"):
        if cfg.family == "vlm":
            s_text = s - cfg.n_vision_tokens
            assert s_text > 0, "shape too small for vision tokens"
            batch = {
                "tokens": arr((b, s_text), jnp.int32),
                "vision_embeds": arr((b, cfg.n_vision_tokens, cfg.d_model),
                                     COMPUTE_DTYPE),
            }
        elif cfg.family == "encdec":
            batch = {
                "tokens": arr((b, s), jnp.int32),
                "frames": arr((b, cfg.enc_seq, cfg.d_model), COMPUTE_DTYPE),
            }
        else:
            batch = {"tokens": arr((b, s), jnp.int32)}
        if shape.kind == "train":
            batch["labels"] = arr(
                (b, s if cfg.family != "vlm" else s - cfg.n_vision_tokens),
                jnp.int32)
        return batch
    if shape.kind == "decode":
        return {
            "token": arr((b, 1), jnp.int32),
            "pos": arr((b,), jnp.int32),
        }
    raise ValueError(shape.kind)


def effective_cache_len(cfg: ModelConfig, shape: ShapeSpec) -> int:
    """Rolling-buffer truncation for windowed archs (DESIGN.md §5)."""
    s = shape.seq_len
    if cfg.family == "hybrid" and cfg.local_window:
        return min(s, cfg.local_window)
    if cfg.sliding_window:
        return min(s, cfg.sliding_window)
    return s


def cache_specs(cfg: ModelConfig, shape: ShapeSpec,
                batch_override: Optional[int] = None):
    """ShapeDtypeStructs of the decode cache via eval_shape (no alloc)."""
    model = build(cfg)
    b = batch_override or shape.global_batch
    clen = effective_cache_len(cfg, shape)
    return jax.eval_shape(lambda: model.init_cache(b, clen))
