"""HLO-text analysis: collective bytes + schedule extraction.

``collective_bytes`` parses ``compiled.as_text()`` and sums the operand
bytes of every all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute. Shapes are parsed from the HLO result/operand types.

Instructions inside ``while`` bodies are counted once per *appearance* —
the roofline harness eliminates that undercount structurally by probing
with fully unrolled programs (DESIGN.md §6), so this parser stays simple
and exact for the programs it is given.
"""
from __future__ import annotations

import dataclasses
import re
from collections import defaultdict

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COLL_KINDS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")
# e.g.:  %all-reduce.5 = f32[64,128]{1,0} all-reduce(%dot), channel_id=...
_INSTR_RE = re.compile(
    r"=\s*(\([^)]*\)|\S+)\s+(" + "|".join(_COLL_KINDS) +
    r")(-start|-done)?\(")


def shape_bytes(type_str: str) -> int:
    """'f32[64,128]{1,0}' → bytes. Tuples: sum of components."""
    total = 0
    for dtype, dims in _SHAPE_RE.findall(type_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


@dataclasses.dataclass
class CollectiveStats:
    total_bytes: int
    by_kind: dict
    count: int
    schedule: list  # (kind, bytes, replica_groups snippet) in program order


def collective_stats(hlo_text: str) -> CollectiveStats:
    total = 0
    by_kind: dict = defaultdict(int)
    schedule = []
    for line in hlo_text.splitlines():
        m = _INSTR_RE.search(line)
        if not m:
            continue
        if m.group(3) == "-done":
            continue  # async pair: count the -start only
        kind = m.group(2)
        nbytes = shape_bytes(m.group(1))
        rg = ""
        rgm = re.search(r"replica_groups=(\S+?)(,|$| )", line)
        if rgm:
            rg = rgm.group(1)[:48]
        total += nbytes
        by_kind[kind] += nbytes
        schedule.append((kind, nbytes, rg))
    return CollectiveStats(total_bytes=total, by_kind=dict(by_kind),
                           count=len(schedule), schedule=schedule)
