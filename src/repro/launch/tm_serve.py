"""TM serving benchmark CLI — a thin layer over ``repro.serving``.

    PYTHONPATH=src python -m repro.launch.tm_serve --smoke
    PYTHONPATH=src python -m repro.launch.tm_serve \
        --engine indexed,bitpack_xla --requests 2048 --rps 4000
    XLA_FLAGS=--xla_force_host_platform_device_count=4 \
        PYTHONPATH=src python -m repro.launch.tm_serve --data-shards 4

The serving runtime itself lives in ``src/repro/serving/`` (DESIGN.md
§10): an AOT bucket cache compiles every padding bucket up front
(``serving/aot.py``), ``AsyncTMServer`` overlaps host batching with
device compute behind bounded-backlog admission control and per-tenant
fairness (``serving/runtime.py``), and an open-loop Poisson load
generator sweeps offered rates (``serving/loadgen.py``). This module only
builds sessions, drives the benchmark, and writes the record.

``BENCH_tm_serve.json`` (schema 2, docs/BENCH_SCHEMAS.md; gitignored
scratch like ``BENCH_tm.json``) contains:

  * ``engines`` — the legacy closed-loop records from ``serve_engine``:
    a simulated arrival clock advanced by *measured* compute times
    (deterministic per seed, no sleeps). Kept for latency-percentile
    tracking across PRs; its "throughput" splices compute windows
    end-to-end and is **not** wall-clock comparable (DESIGN.md §10).
  * ``sustained_load`` — the open-loop comparison: a ``SyncTMServer``
    (the old blocking loop behind the modern submit surface) is ramped to
    saturation, then ``AsyncTMServer`` sweeps an offered-rate ladder
    around that baseline. Same load generator, same wall clock, so
    ``knee_exceeds_sync`` is a fair apples-to-apples claim.
  * ``batch_axis_scaling`` — the same load at 1, 2, … data shards when
    more than one device is available.

The CI smoke (scripts/ci.sh) runs under a forced 4-device host platform
with ``--backend pallas_interpret`` and asserts the record's shape,
including a well-formed ``sustained_load`` with zero hot-loop compiles.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import TMConfig, TMState, registered_engines
from repro.core.session import TMSession, Topology
from repro.data.synthetic import binarized_images
from repro.serving import (
    AOTBucketCache, AsyncTMServer, SyncTMServer, bucket_for, buckets,
    run_step, sustained_load)
from repro.serving.loadgen import holds


@dataclasses.dataclass(frozen=True)
class ServePolicy:
    max_batch: int = 32
    max_wait_ms: float = 2.0  # batching window when the queue is empty


# ``buckets`` / ``_bucket_for`` moved to serving/aot.py with the AOT cache;
# re-exported here because the legacy loop and its tests import them from
# this module.
_bucket_for = bucket_for


def _random_state(cfg: TMConfig, rng: np.random.Generator,
                  include_density: float) -> TMState:
    """Random sparse include state — serving benchmarks measure evaluation,
    not training quality."""
    inc = rng.uniform(size=(cfg.n_classes, cfg.n_clauses,
                            cfg.n_literals)) < include_density
    return TMState(ta_state=jnp.asarray(
        np.where(inc, cfg.n_states + 1, cfg.n_states), jnp.int16))


def serve_engine(session: TMSession, bundle, x_all: np.ndarray,
                 arrivals: np.ndarray, *, engine: str,
                 policy: ServePolicy) -> dict:
    """Run the legacy closed-loop batched loop for one engine.

    The simulated clock advances by measured compute only, so the
    percentiles are clean per-batch compute under a synthetic load — but
    the throughput splices compute windows end-to-end and excludes every
    host-side gap; compare wall-clock claims through ``run_sustained``
    instead (DESIGN.md §10). ``compile_s_per_bucket`` keys are *strings*
    deliberately: the record is JSON, where int keys would be silently
    coerced — emitting them as strings keeps the in-memory record
    identical to a load of the written file (docs/BENCH_SCHEMAS.md).
    """
    sizes = buckets(policy.max_batch,
                    min_batch=session.topology.data_shards)
    o = x_all.shape[1]

    compile_s = {}
    for b in sizes:  # compile every bucket before the timed loop
        t0 = time.perf_counter()
        jax.block_until_ready(
            session.scores(bundle, jnp.zeros((b, o), jnp.uint8),
                           engine=engine))
        compile_s[str(b)] = round(time.perf_counter() - t0, 4)

    n = x_all.shape[0]
    wait = policy.max_wait_ms / 1e3
    clock = float(arrivals[0])
    i = 0
    lat: list[float] = []
    rows_real = rows_padded = n_batches = 0
    cap = sizes[-1]  # top bucket (≤ max_batch, multiple of the data shards)
    while i < n:
        if arrivals[i] > clock:               # idle: admit next + hold window
            clock = float(arrivals[i]) + wait
        k = int(np.searchsorted(arrivals[i:i + cap], clock, side="right"))
        k = max(k, 1)
        b = _bucket_for(k, sizes)
        xp = np.zeros((b, o), np.uint8)
        xp[:k] = x_all[i:i + k]
        t0 = time.perf_counter()
        jax.block_until_ready(
            session.scores(bundle, jnp.asarray(xp), engine=engine))
        done = clock + (time.perf_counter() - t0)
        lat.extend(done - arrivals[i:i + k])
        rows_real += k
        rows_padded += b
        n_batches += 1
        clock = done
        i += k

    lat_ms = np.asarray(lat) * 1e3
    p50, p90, p95, p99 = np.percentile(lat_ms, [50, 90, 95, 99])
    throughput = n / (clock - float(arrivals[0]))
    offered = n / (float(arrivals[-1]) - float(arrivals[0]) + 1e-12)
    # Saturated: the engine drains slower than requests arrive, so the queue
    # grows for the whole run and the percentiles measure backlog (they scale
    # with n_requests), not serving latency. Flagged so cross-PR tracking
    # never compares a backlog artifact against a real tail latency.
    saturated = throughput < 0.95 * offered
    return {
        "engine": engine,
        "saturated": bool(saturated),
        "requests": n,
        "batches": n_batches,
        "mean_batch": round(rows_real / n_batches, 2),
        "padding_efficiency": round(rows_real / rows_padded, 4),
        "latency_ms": {"p50": round(float(p50), 3),
                       "p90": round(float(p90), 3),
                       "p95": round(float(p95), 3),
                       "p99": round(float(p99), 3),
                       "mean": round(float(lat_ms.mean()), 3),
                       "max": round(float(lat_ms.max()), 3)},
        "throughput_rps": round(throughput, 1),
        "compile_s_per_bucket": compile_s,
    }


def run(cfg: TMConfig, *, engines=("indexed",), topology: Topology | None = None,
        n_requests: int = 512, rps: float = 2000.0,
        policy: ServePolicy = ServePolicy(), seed: int = 0,
        include_density: float = 0.08) -> dict:
    """Serve a synthetic load through each engine on one topology."""
    rng = np.random.default_rng(seed)
    session = TMSession(cfg, topology, engines=engines)
    bundle = session.prepare(_random_state(cfg, rng, include_density))

    x_all, _ = binarized_images(n_requests, cfg.n_features, cfg.n_classes,
                                seed=seed + 1)
    arrivals = np.cumsum(rng.exponential(1.0 / rps, n_requests))

    record = {
        "config": {"n_classes": cfg.n_classes, "n_clauses": cfg.n_clauses,
                   "n_features": cfg.n_features},
        "load": {"requests": n_requests, "rps": rps},
        "policy": {"max_batch": policy.max_batch,
                   "max_wait_ms": policy.max_wait_ms},
        "devices": jax.local_device_count(),
        "topology": session.describe(),
        "engines": {},
    }
    for engine in engines:
        record["engines"][engine] = serve_engine(
            session, bundle, x_all, arrivals, engine=engine, policy=policy)
    return record


def _saturation_rps(server, xs: np.ndarray, *, step_duration_s: float,
                    rng: np.random.Generator,
                    start_rps: float = 250.0) -> tuple[float, list[dict]]:
    """Ramp offered load ×4 until the server stops holding it.

    An overloaded open-loop step keeps the server continuously busy, so the
    achieved
    rate of the first non-holding step *is* the server's capacity; the max
    achieved across the ramp is returned to absorb step noise.
    """
    steps, rate = [], start_rps
    while rate <= 4e6:
        step = run_step(server, xs, rps=rate, duration_s=step_duration_s,
                        rng=rng)
        steps.append(step)
        if not holds(step):
            break
        rate *= 4
    return max(s["achieved_rps"] for s in steps), steps


# offered-rate ladder for the async sweep, as multiples of the measured
# sync baseline — dense around 1.0 so the knee resolves whether the async
# runtime clears the baseline, with overload steps past it
ASYNC_LADDER = (0.4, 0.8, 1.05, 1.3, 1.8, 2.6)


def run_sustained(cfg: TMConfig, *, engines=("indexed",),
                  topology: Topology | None = None, max_batch: int = 32,
                  step_duration_s: float = 1.0, seed: int = 0,
                  include_density: float = 0.08) -> dict:
    """The open-loop sync-vs-async comparison (``sustained_load`` section
    of the schema-2 record).

    Per engine: a ``SyncTMServer`` — the old blocking drain loop behind
    the modern submit surface — is ramped to saturation, then an
    ``AsyncTMServer`` over a shared AOT bucket cache sweeps an offered
    ladder scaled to that baseline. Both modes run through the *same*
    Poisson load generator on the same wall clock, so
    ``knee_exceeds_sync`` is a fair claim (unlike the legacy
    ``serve_engine`` throughput, whose simulated clock splices compute
    windows — DESIGN.md §10).
    """
    rng = np.random.default_rng(seed)
    session = TMSession(cfg, topology, engines=engines)
    bundle = session.prepare(_random_state(cfg, rng, include_density))
    xs, _ = binarized_images(512, cfg.n_features, cfg.n_classes,
                             seed=seed + 1)
    aot = AOTBucketCache(session, bundle, engines=tuple(engines),
                         max_batch=max_batch)
    out = {"step_duration_s": step_duration_s,
           "ladder": list(ASYNC_LADDER), "engines": {}}
    for engine in engines:
        sync = SyncTMServer(session, bundle, engine=engine,
                            max_batch=max_batch).start()
        base, ramp = _saturation_rps(
            sync, xs, step_duration_s=step_duration_s,
            rng=np.random.default_rng(seed + 2))
        sync.stop()

        server = AsyncTMServer(session, bundle, engine=engine,
                               max_batch=max_batch, aot=aot).start()
        rec = sustained_load(server, xs,
                             rps_steps=[m * base for m in ASYNC_LADDER],
                             step_duration_s=step_duration_s,
                             seed=seed + 3)
        server.stop()

        rec["sync_baseline"] = {
            "achieved_rps": base,
            "ramp": [{"offered_rps": s["offered_rps"],
                      "achieved_rps": s["achieved_rps"],
                      "rejection_rate": s["rejection_rate"]}
                     for s in ramp]}
        rec["knee_exceeds_sync"] = bool(rec["knee"]["achieved_rps"] > base)
        rec["speedup_at_knee"] = (
            round(rec["knee"]["achieved_rps"] / base, 3) if base else None)
        out["engines"][engine] = rec
    out["compile_s_per_bucket"] = aot.compile_report()
    out["knee_exceeds_sync"] = all(
        r["knee_exceeds_sync"] for r in out["engines"].values())
    return out


def run_batch_axis_scaling(cfg: TMConfig, *, engine: str = "indexed",
                           device_counts=None, n_requests: int = 256,
                           rps: float = 2000.0,
                           policy: ServePolicy = ServePolicy(),
                           seed: int = 0, include_density: float = 0.08,
                           backend: str | None = None,
                           reuse: dict | None = None) -> list[dict]:
    """The same load at 1, 2, … data shards: batch-axis scaling per device
    count (the scores path is communication-free over ``data``, so this is
    the ROADMAP's multi-device ``tm_serve`` measurement).

    ``backend`` is the kernel backend of the *whole* sweep — it must match
    the caller's serving backend, or the per-device-count rows would mix
    kernel routes with incomparable magnitudes (interpret-mode Pallas vs
    compiled XLA). ``reuse`` maps a device count to an already-measured
    ``serve_engine`` record for the identical load *and backend* (e.g. the
    caller's main record), so that count is not benchmarked twice.
    """
    if device_counts is None:
        device_counts, d = [], 1
        while d <= min(jax.local_device_count(), policy.max_batch):
            device_counts.append(d)
            d *= 2
    out = []
    for d in device_counts:
        r = (reuse or {}).get(d)
        if r is None:
            rec = run(cfg, engines=(engine,),
                      topology=Topology(data_shards=d, backend=backend),
                      n_requests=n_requests, rps=rps, policy=policy,
                      seed=seed, include_density=include_density)
            r = rec["engines"][engine]
        out.append({"devices": d, "data_shards": d, "engine": engine,
                    "throughput_rps": r["throughput_rps"],
                    "p50_ms": r["latency_ms"]["p50"],
                    "p95_ms": r["latency_ms"]["p95"],
                    "saturated": r["saturated"]})
    return out


# --smoke supplies these as *defaults* — any explicitly-passed flag wins
# (bitpack in the smoke engine set resolves through the kernel backend
# registry, so CI's --backend pallas_interpret exercises that route)
SMOKE_DEFAULTS = {"engine": "indexed,bitpack", "classes": 4, "clauses": 64,
                  "features": 48, "requests": 96, "max_batch": 8,
                  "step_duration": 0.3}
FULL_DEFAULTS = {"engine": "indexed", "classes": 10, "clauses": 256,
                 "features": 196, "requests": 512, "max_batch": 32,
                 "step_duration": 1.0}


def resolve_flags(smoke: bool, **flags) -> dict:
    """Merge CLI flags with the mode's defaults.

    ``--smoke`` selects a *default set*, never an override: a flag the
    user passed explicitly (non-None) always wins. The old CLI silently
    discarded explicit ``--requests``/``--max-batch``/``--classes``/
    ``--clauses``/``--features`` whenever ``--smoke`` was set.
    """
    base = SMOKE_DEFAULTS if smoke else FULL_DEFAULTS
    unknown = set(flags) - set(base)
    if unknown:
        raise ValueError(f"unknown flags {sorted(unknown)}; "
                         f"resolvable: {sorted(base)}")
    return {k: (base[k] if v is None else v) for k, v in flags.items()}


def main() -> None:
    ap = argparse.ArgumentParser(description="batched TM serving benchmark")
    ap.add_argument("--engine", default=None,
                    help="comma-separated registry engine names")
    ap.add_argument("--requests", type=int, default=None)
    ap.add_argument("--rps", type=float, default=2000.0)
    ap.add_argument("--max-batch", type=int, default=None)
    ap.add_argument("--max-wait-ms", type=float, default=2.0)
    ap.add_argument("--classes", type=int, default=None)
    ap.add_argument("--clauses", type=int, default=None)
    ap.add_argument("--features", type=int, default=None)
    ap.add_argument("--step-duration", type=float, default=None,
                    help="seconds per open-loop load step (sustained_load)")
    ap.add_argument("--data-shards", type=int, default=None,
                    help="serve data-sharded over this many devices "
                         "(default: all available)")
    ap.add_argument("--clause-shards", type=int, default=1)
    from repro.kernels.backend import BACKENDS
    ap.add_argument("--backend", default=None, choices=list(BACKENDS),
                    help="kernel backend the TM primitives resolve through "
                         "(kernels/backend.py; default: TMConfig's 'auto')")
    ap.add_argument("--no-scaling", action="store_true",
                    help="skip the per-device-count batch-axis sweep")
    ap.add_argument("--no-sustained", action="store_true",
                    help="skip the open-loop sync-vs-async sustained_load "
                         "sweep")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default="BENCH_tm_serve.json")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny defaults for CI (scripts/ci.sh); explicit "
                         "flags still win")
    args = ap.parse_args()

    n_dev = jax.local_device_count()
    r = resolve_flags(args.smoke, engine=args.engine, classes=args.classes,
                      clauses=args.clauses, features=args.features,
                      requests=args.requests, max_batch=args.max_batch,
                      step_duration=args.step_duration)
    cfg = TMConfig(n_classes=r["classes"], n_clauses=r["clauses"],
                   n_features=r["features"])
    engines = tuple(r["engine"].split(","))
    n_requests, max_batch = r["requests"], r["max_batch"]
    for e in engines:
        if e not in registered_engines():
            raise SystemExit(f"unknown engine {e!r}; "
                             f"registered: {registered_engines()}")

    # default placement: spread spare devices over data, but never beyond
    # max_batch (batches must divide over the data axis — buckets() errors
    # on an explicit --data-shards that violates this)
    data_shards = (args.data_shards if args.data_shards is not None
                   else min(max(n_dev // args.clause_shards, 1), max_batch))
    topology = Topology(data_shards=data_shards,
                        clause_shards=args.clause_shards,
                        backend=args.backend)
    policy = ServePolicy(max_batch=max_batch, max_wait_ms=args.max_wait_ms)
    record = run(cfg, engines=engines, topology=topology,
                 n_requests=n_requests, rps=args.rps, policy=policy,
                 seed=args.seed)
    record["schema"] = 2
    if not args.no_sustained:
        record["sustained_load"] = run_sustained(
            cfg, engines=engines, topology=topology, max_batch=max_batch,
            step_duration_s=r["step_duration"], seed=args.seed)
    if not args.no_scaling and n_dev > 1:
        sweep_requests = (min(n_requests, 256) if not args.smoke
                          else n_requests)
        # the main record already measured this exact point — don't redo it
        reuse = ({data_shards: record["engines"][engines[0]]}
                 if args.clause_shards == 1 and sweep_requests == n_requests
                 else None)
        record["batch_axis_scaling"] = run_batch_axis_scaling(
            cfg, engine=engines[0], n_requests=sweep_requests,
            rps=args.rps, policy=policy, seed=args.seed,
            backend=args.backend, reuse=reuse)

    with open(args.out, "w") as f:
        json.dump(record, f, indent=2)
    topo = record["topology"]
    print(f"topology: {topo['data_shards']}×data · {topo['clause_shards']}"
          f"×clause on {record['devices']} devices "
          f"({'sharded' if topo['sharded'] else 'single-device'} scores "
          f"path, backend={topo['backend']}, "
          f"composition={topo['composition']})")
    for name, r in record["engines"].items():
        lm = r["latency_ms"]
        tag = "  [SATURATED: offered load > capacity; percentiles are " \
              "backlog, lower --rps]" if r["saturated"] else ""
        print(f"{name}: p50={lm['p50']}ms p95={lm['p95']}ms "
              f"p99={lm['p99']}ms thru={r['throughput_rps']}req/s "
              f"pad_eff={r['padding_efficiency']}{tag}")
    for name, s in record.get("sustained_load", {}).get("engines",
                                                        {}).items():
        knee = s["knee"]
        print(f"sustained[{name}]: sync={s['sync_baseline']['achieved_rps']}"
              f"req/s · async knee={knee['achieved_rps']}req/s at offered "
              f"{knee['offered_rps']} ({s['speedup_at_knee']}x sync, "
              f"exceeds={s['knee_exceeds_sync']}, hot-loop compiles="
              f"{s['aot']['hot_loop_compiles']})")
    for row in record.get("batch_axis_scaling", []):
        print(f"scaling[{row['engine']}] devices={row['devices']}: "
              f"thru={row['throughput_rps']}req/s p95={row['p95_ms']}ms")
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
