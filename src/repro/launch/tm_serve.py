"""Batched TM serving through a ``TMSession``: pad/bucket incoming requests,
run a registry engine on any topology, report tail latency + throughput.

    PYTHONPATH=src python -m repro.launch.tm_serve --smoke
    PYTHONPATH=src python -m repro.launch.tm_serve \
        --engine indexed,bitpack_xla --requests 2048 --rps 4000
    XLA_FLAGS=--xla_force_host_platform_device_count=4 \
        PYTHONPATH=src python -m repro.launch.tm_serve --data-shards 4

The serving loop is the TM analogue of ``launch/serve.py``'s LM loop, built
on the session API (core/session.py): one ``TMBundle`` carries the
maintained cache of whichever engine serves, and inference is a single
jitted ``session.scores`` call per batch — the single-device graph on a
1-device topology, the clause-sharded ``make_sharded_scores`` shard_map
path (one (B, m) vote all-reduce; batch sharded over the ``data`` axis
communication-free) on a multi-device mesh. The serve loop itself never
branches on placement.

Batching policy (DESIGN.md §6): requests queue with their arrival time;
when the server frees up it takes everything queued (capped at
``max_batch``); when idle it admits the next arrival and holds a
``max_wait_ms`` window to accumulate a batch. Batches pad to power-of-two
buckets so every shape compiles exactly once (compile time is measured
separately up front, never inside the latency loop); on a data-sharded
topology the smallest bucket is the data-shard count so every batch
divides over the mesh. The loop runs on a simulated arrival clock advanced
by *measured* compute times, so the percentiles are real compute under a
synthetic load — deterministic per seed, no sleeps.

Emits ``BENCH_tm_serve.json`` (gitignored scratch, like ``BENCH_tm.json``)
with per-engine latency percentiles, throughput, padding efficiency, the
serving topology, and — when more than one device is available — a
``batch_axis_scaling`` sweep: the same load served at 1, 2, … data shards,
so batch-axis scaling is visible per device count. The CI smoke
(scripts/ci.sh) runs under a forced 4-device host platform and asserts the
device count and the sweep are recorded.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import TMConfig, TMState, registered_engines
from repro.core.session import TMSession, Topology
from repro.data.synthetic import binarized_images


@dataclasses.dataclass(frozen=True)
class ServePolicy:
    max_batch: int = 32
    max_wait_ms: float = 2.0  # batching window when the queue is empty


def buckets(max_batch: int, min_batch: int = 1) -> list[int]:
    """Power-of-two padding buckets in [min_batch, max_batch].

    ``min_batch`` is the serving topology's data-shard count: every padded
    batch must divide over the mesh ``data`` axis, so a top bucket that is
    not a multiple of ``min_batch`` rounds *down* to one (the serve loop
    caps admission at the top bucket).
    """
    if min_batch > max_batch:
        raise ValueError(
            f"max_batch={max_batch} < data shards={min_batch}: every "
            "batch must divide over the data axis — raise max_batch or "
            "serve with fewer data shards")
    out = [min_batch]
    while out[-1] < max_batch:
        nxt = min(out[-1] * 2, max_batch)
        if nxt % min_batch:
            nxt = max(min_batch, (nxt // min_batch) * min_batch)
            if nxt == out[-1]:
                break
        out.append(nxt)
    return out


def _bucket_for(n: int, sizes: list[int]) -> int:
    for b in sizes:
        if b >= n:
            return b
    return sizes[-1]


def _random_state(cfg: TMConfig, rng: np.random.Generator,
                  include_density: float) -> TMState:
    """Random sparse include state — serving benchmarks measure evaluation,
    not training quality."""
    inc = rng.uniform(size=(cfg.n_classes, cfg.n_clauses,
                            cfg.n_literals)) < include_density
    return TMState(ta_state=jnp.asarray(
        np.where(inc, cfg.n_states + 1, cfg.n_states), jnp.int16))


def serve_engine(session: TMSession, bundle, x_all: np.ndarray,
                 arrivals: np.ndarray, *, engine: str,
                 policy: ServePolicy) -> dict:
    """Run the batched loop for one engine; returns its stats record."""
    sizes = buckets(policy.max_batch,
                    min_batch=session.topology.data_shards)
    o = x_all.shape[1]

    compile_s = {}
    for b in sizes:  # compile every bucket before the timed loop
        t0 = time.perf_counter()
        jax.block_until_ready(
            session.scores(bundle, jnp.zeros((b, o), jnp.uint8),
                           engine=engine))
        compile_s[b] = round(time.perf_counter() - t0, 4)

    n = x_all.shape[0]
    wait = policy.max_wait_ms / 1e3
    clock = float(arrivals[0])
    i = 0
    lat: list[float] = []
    rows_real = rows_padded = n_batches = 0
    cap = sizes[-1]  # top bucket (≤ max_batch, multiple of the data shards)
    while i < n:
        if arrivals[i] > clock:               # idle: admit next + hold window
            clock = float(arrivals[i]) + wait
        k = int(np.searchsorted(arrivals[i:i + cap], clock, side="right"))
        k = max(k, 1)
        b = _bucket_for(k, sizes)
        xp = np.zeros((b, o), np.uint8)
        xp[:k] = x_all[i:i + k]
        t0 = time.perf_counter()
        jax.block_until_ready(
            session.scores(bundle, jnp.asarray(xp), engine=engine))
        done = clock + (time.perf_counter() - t0)
        lat.extend(done - arrivals[i:i + k])
        rows_real += k
        rows_padded += b
        n_batches += 1
        clock = done
        i += k

    lat_ms = np.asarray(lat) * 1e3
    p50, p90, p95, p99 = np.percentile(lat_ms, [50, 90, 95, 99])
    throughput = n / (clock - float(arrivals[0]))
    offered = n / (float(arrivals[-1]) - float(arrivals[0]) + 1e-12)
    # Saturated: the engine drains slower than requests arrive, so the queue
    # grows for the whole run and the percentiles measure backlog (they scale
    # with n_requests), not serving latency. Flagged so cross-PR tracking
    # never compares a backlog artifact against a real tail latency.
    saturated = throughput < 0.95 * offered
    return {
        "engine": engine,
        "saturated": bool(saturated),
        "requests": n,
        "batches": n_batches,
        "mean_batch": round(rows_real / n_batches, 2),
        "padding_efficiency": round(rows_real / rows_padded, 4),
        "latency_ms": {"p50": round(float(p50), 3),
                       "p90": round(float(p90), 3),
                       "p95": round(float(p95), 3),
                       "p99": round(float(p99), 3),
                       "mean": round(float(lat_ms.mean()), 3),
                       "max": round(float(lat_ms.max()), 3)},
        "throughput_rps": round(throughput, 1),
        "compile_s_per_bucket": compile_s,
    }


def run(cfg: TMConfig, *, engines=("indexed",), topology: Topology | None = None,
        n_requests: int = 512, rps: float = 2000.0,
        policy: ServePolicy = ServePolicy(), seed: int = 0,
        include_density: float = 0.08) -> dict:
    """Serve a synthetic load through each engine on one topology."""
    rng = np.random.default_rng(seed)
    session = TMSession(cfg, topology, engines=engines)
    bundle = session.prepare(_random_state(cfg, rng, include_density))

    x_all, _ = binarized_images(n_requests, cfg.n_features, cfg.n_classes,
                                seed=seed + 1)
    arrivals = np.cumsum(rng.exponential(1.0 / rps, n_requests))

    record = {
        "config": {"n_classes": cfg.n_classes, "n_clauses": cfg.n_clauses,
                   "n_features": cfg.n_features},
        "load": {"requests": n_requests, "rps": rps},
        "policy": {"max_batch": policy.max_batch,
                   "max_wait_ms": policy.max_wait_ms},
        "devices": jax.local_device_count(),
        "topology": session.describe(),
        "engines": {},
    }
    for engine in engines:
        record["engines"][engine] = serve_engine(
            session, bundle, x_all, arrivals, engine=engine, policy=policy)
    return record


def run_batch_axis_scaling(cfg: TMConfig, *, engine: str = "indexed",
                           device_counts=None, n_requests: int = 256,
                           rps: float = 2000.0,
                           policy: ServePolicy = ServePolicy(),
                           seed: int = 0, include_density: float = 0.08,
                           backend: str | None = None,
                           reuse: dict | None = None) -> list[dict]:
    """The same load at 1, 2, … data shards: batch-axis scaling per device
    count (the scores path is communication-free over ``data``, so this is
    the ROADMAP's multi-device ``tm_serve`` measurement).

    ``backend`` is the kernel backend of the *whole* sweep — it must match
    the caller's serving backend, or the per-device-count rows would mix
    kernel routes with incomparable magnitudes (interpret-mode Pallas vs
    compiled XLA). ``reuse`` maps a device count to an already-measured
    ``serve_engine`` record for the identical load *and backend* (e.g. the
    caller's main record), so that count is not benchmarked twice.
    """
    if device_counts is None:
        device_counts, d = [], 1
        while d <= min(jax.local_device_count(), policy.max_batch):
            device_counts.append(d)
            d *= 2
    out = []
    for d in device_counts:
        r = (reuse or {}).get(d)
        if r is None:
            rec = run(cfg, engines=(engine,),
                      topology=Topology(data_shards=d, backend=backend),
                      n_requests=n_requests, rps=rps, policy=policy,
                      seed=seed, include_density=include_density)
            r = rec["engines"][engine]
        out.append({"devices": d, "data_shards": d, "engine": engine,
                    "throughput_rps": r["throughput_rps"],
                    "p50_ms": r["latency_ms"]["p50"],
                    "p95_ms": r["latency_ms"]["p95"],
                    "saturated": r["saturated"]})
    return out


def main() -> None:
    ap = argparse.ArgumentParser(description="batched TM serving benchmark")
    ap.add_argument("--engine", default="indexed",
                    help="comma-separated registry engine names")
    ap.add_argument("--requests", type=int, default=512)
    ap.add_argument("--rps", type=float, default=2000.0)
    ap.add_argument("--max-batch", type=int, default=32)
    ap.add_argument("--max-wait-ms", type=float, default=2.0)
    ap.add_argument("--classes", type=int, default=10)
    ap.add_argument("--clauses", type=int, default=256)
    ap.add_argument("--features", type=int, default=196)
    ap.add_argument("--data-shards", type=int, default=None,
                    help="serve data-sharded over this many devices "
                         "(default: all available)")
    ap.add_argument("--clause-shards", type=int, default=1)
    from repro.kernels.backend import BACKENDS
    ap.add_argument("--backend", default=None, choices=list(BACKENDS),
                    help="kernel backend the TM primitives resolve through "
                         "(kernels/backend.py; default: TMConfig's 'auto')")
    ap.add_argument("--no-scaling", action="store_true",
                    help="skip the per-device-count batch-axis sweep")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default="BENCH_tm_serve.json")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny load for CI (scripts/ci.sh)")
    args = ap.parse_args()

    n_dev = jax.local_device_count()
    if args.smoke:
        cfg = TMConfig(n_classes=4, n_clauses=64, n_features=48)
        # bitpack resolves through the kernel backend registry, so the smoke
        # exercises whatever --backend selects (CI: pallas_interpret)
        engines = ("indexed", "bitpack")
        n_requests, max_batch = 96, 8
    else:
        cfg = TMConfig(n_classes=args.classes, n_clauses=args.clauses,
                       n_features=args.features)
        engines = tuple(args.engine.split(","))
        n_requests, max_batch = args.requests, args.max_batch
    for e in engines:
        if e not in registered_engines():
            raise SystemExit(f"unknown engine {e!r}; "
                             f"registered: {registered_engines()}")

    # default placement: spread spare devices over data, but never beyond
    # max_batch (batches must divide over the data axis — buckets() errors
    # on an explicit --data-shards that violates this)
    data_shards = (args.data_shards if args.data_shards is not None
                   else min(max(n_dev // args.clause_shards, 1), max_batch))
    topology = Topology(data_shards=data_shards,
                        clause_shards=args.clause_shards,
                        backend=args.backend)
    policy = ServePolicy(max_batch=max_batch, max_wait_ms=args.max_wait_ms)
    record = run(cfg, engines=engines, topology=topology,
                 n_requests=n_requests, rps=args.rps, policy=policy,
                 seed=args.seed)
    if not args.no_scaling and n_dev > 1:
        sweep_requests = (min(n_requests, 256) if not args.smoke
                          else n_requests)
        # the main record already measured this exact point — don't redo it
        reuse = ({data_shards: record["engines"][engines[0]]}
                 if args.clause_shards == 1 and sweep_requests == n_requests
                 else None)
        record["batch_axis_scaling"] = run_batch_axis_scaling(
            cfg, engine=engines[0], n_requests=sweep_requests,
            rps=args.rps, policy=policy, seed=args.seed,
            backend=args.backend, reuse=reuse)

    with open(args.out, "w") as f:
        json.dump(record, f, indent=2)
    topo = record["topology"]
    print(f"topology: {topo['data_shards']}×data · {topo['clause_shards']}"
          f"×clause on {record['devices']} devices "
          f"({'sharded' if topo['sharded'] else 'single-device'} scores "
          f"path, backend={topo['backend']}, "
          f"composition={topo['composition']})")
    for name, r in record["engines"].items():
        lm = r["latency_ms"]
        tag = "  [SATURATED: offered load > capacity; percentiles are " \
              "backlog, lower --rps]" if r["saturated"] else ""
        print(f"{name}: p50={lm['p50']}ms p95={lm['p95']}ms "
              f"p99={lm['p99']}ms thru={r['throughput_rps']}req/s "
              f"pad_eff={r['padding_efficiency']}{tag}")
    for row in record.get("batch_axis_scaling", []):
        print(f"scaling[{row['engine']}] devices={row['devices']}: "
              f"thru={row['throughput_rps']}req/s p95={row['p95_ms']}ms")
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
