"""Batched TM serving: pad/bucket incoming requests, run a registry engine,
report tail latency + throughput.

    PYTHONPATH=src python -m repro.launch.tm_serve --smoke
    PYTHONPATH=src python -m repro.launch.tm_serve \
        --engine indexed,bitpack_xla --requests 2048 --rps 4000

The serving loop is the TM analogue of ``launch/serve.py``'s LM loop, built
on the PR-1 bundle API: one ``TMBundle`` carries the maintained cache of
whichever engine serves, and inference is a single jitted ``bundle_scores``
call per batch.

Batching policy (DESIGN.md §6): requests queue with their arrival time;
when the server frees up it takes everything queued (capped at
``max_batch``); when idle it admits the next arrival and holds a
``max_wait_ms`` window to accumulate a batch. Batches pad to power-of-two
buckets so every shape compiles exactly once (compile time is measured
separately up front, never inside the latency loop). The loop runs on a
simulated arrival clock advanced by *measured* compute times, so the
percentiles are real compute under a synthetic load — deterministic per
seed, no sleeps.

Emits ``BENCH_tm_serve.json`` (gitignored scratch, like ``BENCH_tm.json``)
with per-engine latency percentiles, throughput, and padding efficiency —
the CI smoke (scripts/ci.sh) asserts the file is well-formed.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import TMConfig, TMState, registered_engines
from repro.core.api import bundle_scores, init_bundle
from repro.data.synthetic import binarized_images


@dataclasses.dataclass(frozen=True)
class ServePolicy:
    max_batch: int = 32
    max_wait_ms: float = 2.0  # batching window when the queue is empty


def buckets(max_batch: int) -> list[int]:
    """Power-of-two padding buckets up to (and including) max_batch."""
    out = [1]
    while out[-1] < max_batch:
        out.append(min(out[-1] * 2, max_batch))
    return out


def _bucket_for(n: int, sizes: list[int]) -> int:
    for b in sizes:
        if b >= n:
            return b
    return sizes[-1]


_scores_jit = jax.jit(bundle_scores, static_argnames=("engine",))


def serve_engine(bundle, x_all: np.ndarray, arrivals: np.ndarray, *,
                 engine: str, policy: ServePolicy) -> dict:
    """Run the batched loop for one engine; returns its stats record."""
    sizes = buckets(policy.max_batch)
    o = x_all.shape[1]

    compile_s = {}
    for b in sizes:  # compile every bucket before the timed loop
        t0 = time.perf_counter()
        jax.block_until_ready(
            _scores_jit(bundle, jnp.zeros((b, o), jnp.uint8), engine=engine))
        compile_s[b] = round(time.perf_counter() - t0, 4)

    n = x_all.shape[0]
    wait = policy.max_wait_ms / 1e3
    clock = float(arrivals[0])
    i = 0
    lat: list[float] = []
    rows_real = rows_padded = n_batches = 0
    while i < n:
        if arrivals[i] > clock:               # idle: admit next + hold window
            clock = float(arrivals[i]) + wait
        k = int(np.searchsorted(arrivals[i:i + policy.max_batch], clock,
                                side="right"))
        k = max(k, 1)
        b = _bucket_for(k, sizes)
        xp = np.zeros((b, o), np.uint8)
        xp[:k] = x_all[i:i + k]
        t0 = time.perf_counter()
        jax.block_until_ready(_scores_jit(bundle, jnp.asarray(xp),
                                          engine=engine))
        done = clock + (time.perf_counter() - t0)
        lat.extend(done - arrivals[i:i + k])
        rows_real += k
        rows_padded += b
        n_batches += 1
        clock = done
        i += k

    lat_ms = np.asarray(lat) * 1e3
    p50, p90, p95, p99 = np.percentile(lat_ms, [50, 90, 95, 99])
    throughput = n / (clock - float(arrivals[0]))
    offered = n / (float(arrivals[-1]) - float(arrivals[0]) + 1e-12)
    # Saturated: the engine drains slower than requests arrive, so the queue
    # grows for the whole run and the percentiles measure backlog (they scale
    # with n_requests), not serving latency. Flagged so cross-PR tracking
    # never compares a backlog artifact against a real tail latency.
    saturated = throughput < 0.95 * offered
    return {
        "engine": engine,
        "saturated": bool(saturated),
        "requests": n,
        "batches": n_batches,
        "mean_batch": round(rows_real / n_batches, 2),
        "padding_efficiency": round(rows_real / rows_padded, 4),
        "latency_ms": {"p50": round(float(p50), 3),
                       "p90": round(float(p90), 3),
                       "p95": round(float(p95), 3),
                       "p99": round(float(p99), 3),
                       "mean": round(float(lat_ms.mean()), 3),
                       "max": round(float(lat_ms.max()), 3)},
        "throughput_rps": round(throughput, 1),
        "compile_s_per_bucket": compile_s,
    }


def run(cfg: TMConfig, *, engines=("indexed",), n_requests: int = 512,
        rps: float = 2000.0, policy: ServePolicy = ServePolicy(),
        seed: int = 0, include_density: float = 0.08) -> dict:
    """Serve a synthetic load through each engine; returns the JSON record.

    The model is a random sparse include state (serving benchmarks measure
    evaluation, not training quality); each requested engine's cache is
    prepared once into the bundle and maintained from then on.
    """
    rng = np.random.default_rng(seed)
    inc = rng.uniform(size=(cfg.n_classes, cfg.n_clauses,
                            cfg.n_literals)) < include_density
    state = TMState(ta_state=jnp.asarray(
        np.where(inc, cfg.n_states + 1, cfg.n_states), jnp.int16))
    bundle = init_bundle(cfg, engines=engines, state=state)

    x_all, _ = binarized_images(n_requests, cfg.n_features, cfg.n_classes,
                                seed=seed + 1)
    arrivals = np.cumsum(rng.exponential(1.0 / rps, n_requests))

    record = {
        "config": {"n_classes": cfg.n_classes, "n_clauses": cfg.n_clauses,
                   "n_features": cfg.n_features},
        "load": {"requests": n_requests, "rps": rps},
        "policy": {"max_batch": policy.max_batch,
                   "max_wait_ms": policy.max_wait_ms},
        "engines": {},
    }
    for engine in engines:
        record["engines"][engine] = serve_engine(
            bundle, x_all, arrivals, engine=engine, policy=policy)
    return record


def main() -> None:
    ap = argparse.ArgumentParser(description="batched TM serving benchmark")
    ap.add_argument("--engine", default="indexed",
                    help="comma-separated registry engine names")
    ap.add_argument("--requests", type=int, default=512)
    ap.add_argument("--rps", type=float, default=2000.0)
    ap.add_argument("--max-batch", type=int, default=32)
    ap.add_argument("--max-wait-ms", type=float, default=2.0)
    ap.add_argument("--classes", type=int, default=10)
    ap.add_argument("--clauses", type=int, default=256)
    ap.add_argument("--features", type=int, default=196)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default="BENCH_tm_serve.json")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny load for CI (scripts/ci.sh)")
    args = ap.parse_args()

    if args.smoke:
        cfg = TMConfig(n_classes=4, n_clauses=64, n_features=48)
        engines = ("indexed", "bitpack_xla")
        n_requests, max_batch = 96, 8
    else:
        cfg = TMConfig(n_classes=args.classes, n_clauses=args.clauses,
                       n_features=args.features)
        engines = tuple(args.engine.split(","))
        n_requests, max_batch = args.requests, args.max_batch
    for e in engines:
        if e not in registered_engines():
            raise SystemExit(f"unknown engine {e!r}; "
                             f"registered: {registered_engines()}")

    record = run(cfg, engines=engines, n_requests=n_requests, rps=args.rps,
                 policy=ServePolicy(max_batch=max_batch,
                                    max_wait_ms=args.max_wait_ms),
                 seed=args.seed)
    with open(args.out, "w") as f:
        json.dump(record, f, indent=2)
    for name, r in record["engines"].items():
        lm = r["latency_ms"]
        tag = "  [SATURATED: offered load > capacity; percentiles are " \
              "backlog, lower --rps]" if r["saturated"] else ""
        print(f"{name}: p50={lm['p50']}ms p95={lm['p95']}ms "
              f"p99={lm['p99']}ms thru={r['throughput_rps']}req/s "
              f"pad_eff={r['padding_efficiency']}{tag}")
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
