"""Production meshes (TPU v5e pods).

Never touches jax device state at import time — meshes are built inside
functions only (the dry-run sets XLA_FLAGS before any jax import).
"""
from __future__ import annotations

import jax


def mesh_context(mesh):
    """``jax.set_mesh(mesh)`` where available; the ``Mesh`` context manager
    on older jax (0.4.x has no ``jax.set_mesh`` — entering the mesh itself
    sets the resource env, which is all the explicit-NamedSharding jit
    call sites here need)."""
    set_mesh = getattr(jax, "set_mesh", None)
    return set_mesh(mesh) if set_mesh is not None else mesh


def _mesh_kwargs(n_axes: int) -> dict:
    """``axis_types=`` only where the installed jax supports it.

    ``jax.sharding.AxisType`` landed after 0.4.37 (the container's jax);
    older versions treat every axis as Auto already, so omitting the kwarg
    is semantically identical there.
    """
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return {}
    return {"axis_types": (axis_type.Auto,) * n_axes}


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 single-pod (256 chips) or 2×16×16 multi-pod (512 chips).

    Axes: ``pod`` — pure data parallel across pods (DCN);
    ``data`` — FSDP + batch; ``model`` — TP / SP / seq-sharded KV.
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = 1
    for s in shape:
        n *= s
    devices = jax.devices()[:n]
    if len(devices) < n:
        raise RuntimeError(
            f"need {n} devices (got {len(devices)}); the dry-run entrypoint "
            "must set XLA_FLAGS=--xla_force_host_platform_device_count=512 "
            "before importing jax")
    import numpy as np
    return jax.sharding.Mesh(
        np.asarray(devices).reshape(shape), axes, **_mesh_kwargs(len(axes)))


def make_host_mesh(data: int = 1, model: int = 1):
    """Small mesh over whatever devices exist (unit tests)."""
    import numpy as np
    n = data * model
    devices = jax.devices()[:n]
    if len(devices) < n:
        raise RuntimeError(f"need {n} devices, have {len(devices)}")
    return jax.sharding.Mesh(
        np.asarray(devices).reshape(data, model), ("data", "model"),
        **_mesh_kwargs(2))
