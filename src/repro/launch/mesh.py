"""Production meshes (TPU v5e pods).

Never touches jax device state at import time — meshes are built inside
functions only (the dry-run sets XLA_FLAGS before any jax import).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 single-pod (256 chips) or 2×16×16 multi-pod (512 chips).

    Axes: ``pod`` — pure data parallel across pods (DCN);
    ``data`` — FSDP + batch; ``model`` — TP / SP / seq-sharded KV.
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = 1
    for s in shape:
        n *= s
    devices = jax.devices()[:n]
    if len(devices) < n:
        raise RuntimeError(
            f"need {n} devices (got {len(devices)}); the dry-run entrypoint "
            "must set XLA_FLAGS=--xla_force_host_platform_device_count=512 "
            "before importing jax")
    import numpy as np
    axis_types = (jax.sharding.AxisType.Auto,) * len(axes)
    return jax.sharding.Mesh(
        np.asarray(devices).reshape(shape), axes, axis_types=axis_types)


def make_host_mesh(data: int = 1, model: int = 1):
    """Small mesh over whatever devices exist (unit tests)."""
    import numpy as np
    n = data * model
    devices = jax.devices()[:n]
    if len(devices) < n:
        raise RuntimeError(f"need {n} devices, have {len(devices)}")
    axis_types = (jax.sharding.AxisType.Auto,) * 2
    return jax.sharding.Mesh(
        np.asarray(devices).reshape(data, model), ("data", "model"),
        axis_types=axis_types)
