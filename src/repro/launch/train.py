"""Training CLI: end-to-end driver on real devices.

On this CPU container it runs reduced configs (--reduced, default) — the
same code path a pod would run: sharded data pipeline → microbatched
train_step → async checkpoints → restart. ``--arch`` picks any assigned
architecture.

    PYTHONPATH=src python -m repro.launch.train --arch qwen3-1.7b \
        --steps 50 --batch 8 --seq 128 --reduced
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config, reduce_config
from repro.configs.base import ShapeSpec
from repro.checkpoint.checkpointer import Checkpointer
from repro.data.pipeline import TokenBatcher
from repro.optim import adamw, compression
from repro.runtime.trainer import Trainer, TrainLoopConfig
from repro.steps import make_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-1.7b")
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--microbatches", type=int, default=2)
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--full", dest="reduced", action="store_false")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--compress", default="none",
                    choices=["none", "bf16", "int8"])
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduce_config(cfg)
    if cfg.family in ("vlm", "encdec"):
        raise SystemExit(
            "train CLI drives token-only batches; use examples/ for "
            "multimodal training loops")
    shape = ShapeSpec("cli", "train", args.seq, args.batch)
    step = make_step(cfg, shape, None, microbatches=args.microbatches,
                     compress=args.compress)

    from repro.models.model import build
    model = build(cfg)
    params = model.init(jax.random.key(0))
    state = {
        "params": params,
        "opt": adamw.init(params),
        "ef": compression.init_error_feedback(params),
    }
    step_fn = jax.jit(step.fn, donate_argnums=(0,))
    batcher = TokenBatcher(cfg.vocab, args.batch, args.seq, seed=0)

    def batch_fn(i):
        b = batcher(i)
        return {k: jnp.asarray(v) for k, v in b.items()}

    trainer = Trainer(
        step_fn=step_fn, state=state, batcher=batch_fn,
        checkpointer=Checkpointer(args.ckpt_dir, keep=2),
        loop=TrainLoopConfig(total_steps=args.steps, ckpt_every=10,
                             log_every=5))
    t0 = time.time()
    end = trainer.run()
    dt = time.time() - t0
    for s, m in trainer.metrics_log:
        print(f"step {s:5d}  loss {m['loss']:.4f}  nll {m['nll']:.4f}  "
              f"gnorm {m['grad_norm']:.3f}")
    toks = args.steps * args.batch * args.seq
    print(f"\ntrained to step {end}: {toks/dt:.0f} tok/s wall "
          f"({dt:.1f}s total)")


if __name__ == "__main__":
    main()
