import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# Env must precede any jax import (same contract as dryrun.py).

if __name__ == "__main__":
    from repro.launch.roofline import main  # noqa: E402
    main()
