"""Serving CLI: batched prefill + decode loop (reduced configs on CPU).

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-1.7b \
        --batch 4 --prompt-len 32 --gen 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, reduce_config
from repro.models.model import build
from repro.sharding import Policy


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-1.7b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()

    cfg = reduce_config(get_config(args.arch))
    if cfg.family in ("vlm", "encdec"):
        raise SystemExit("serve CLI drives token-only prompts")
    model = build(cfg)
    policy = Policy.none()
    params = jax.tree.map(
        lambda x: x.astype(jnp.bfloat16) if x.dtype == jnp.float32 else x,
        model.init(jax.random.key(0)))

    rng = np.random.default_rng(0)
    prompts = jnp.asarray(
        rng.integers(0, cfg.vocab, (args.batch, args.prompt_len)), jnp.int32)
    cache_len = args.prompt_len + args.gen

    prefill = jax.jit(lambda p, t: model.prefill(
        policy, p, cache_len, tokens=t))
    decode = jax.jit(lambda p, tok, c, pos: model.decode_step(
        policy, p, tok, c, pos))

    t0 = time.time()
    logits, cache = prefill(params, prompts)
    jax.block_until_ready(logits)
    t_prefill = time.time() - t0

    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    out = [tok]
    t0 = time.time()
    for i in range(args.gen - 1):
        pos = jnp.full((args.batch,), args.prompt_len + i, jnp.int32)
        logits, cache = decode(params, tok, cache, pos)
        tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
        out.append(tok)
    jax.block_until_ready(tok)
    t_decode = time.time() - t0

    gen = np.asarray(jnp.concatenate(out, axis=1))
    print(f"arch={cfg.name} (reduced) batch={args.batch}")
    print(f"prefill: {args.batch * args.prompt_len / t_prefill:.0f} tok/s "
          f"({t_prefill*1e3:.0f} ms)")
    print(f"decode:  {args.batch * (args.gen-1) / max(t_decode,1e-9):.0f} "
          f"tok/s ({t_decode*1e3/max(args.gen-1,1):.1f} ms/step)")
    print("sample generations (token ids):")
    for b in range(min(2, args.batch)):
        print(f"  [{b}] {gen[b][:12].tolist()}")


if __name__ == "__main__":
    main()
