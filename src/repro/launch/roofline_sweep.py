import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# Env before jax import (same contract as dryrun.py).

import argparse      # noqa: E402
import json          # noqa: E402
import traceback     # noqa: E402

from repro.configs import ARCHS, get_config, shapes_for  # noqa: E402
from repro.launch.roofline import RESULTS, analyze_cell  # noqa: E402


def main():
    ap = argparse.ArgumentParser(
        description="roofline probe sweep (single-pod mesh per brief)")
    ap.add_argument("--arch", default=None)
    ap.add_argument("--tag", default="baseline")
    args = ap.parse_args()

    archs = (args.arch,) if args.arch else ARCHS
    failures = []
    for arch in archs:
        cfg = get_config(arch)
        for shape in shapes_for(cfg):
            out = RESULTS / arch / shape.name / f"16x16.{args.tag}.json"
            if out.exists():
                print(f"[skip-cached] {arch} × {shape.name}")
                continue
            print(f"[roofline] {arch} × {shape.name} ...", flush=True)
            try:
                rec = analyze_cell(arch, shape.name, multi_pod=False,
                                   tag=args.tag)
                t = rec["terms"]
                print(f"  compute={t['compute_s']*1e3:.2f}ms "
                      f"memory={t['memory_s']*1e3:.2f}ms "
                      f"coll={t['collective_s']*1e3:.2f}ms "
                      f"dom={t['dominant']} "
                      f"useful={rec['useful_flops_ratio']:.2f}", flush=True)
            except Exception as e:  # noqa: BLE001
                failures.append((arch, shape.name, repr(e)))
                print(f"  FAIL: {e}\n{traceback.format_exc()}", flush=True)
    if failures:
        print(f"{len(failures)} failures:")
        for f in failures:
            print("  ", f)
        raise SystemExit(1)
    print("roofline sweep complete")


if __name__ == "__main__":
    main()
