"""Assemble EXPERIMENTS.md §Dry-run and §Roofline tables from results/."""
from __future__ import annotations

import json
from pathlib import Path

from repro.configs import ARCHS, SKIPPED_CELLS, get_config, shapes_for

ROOT = Path(__file__).resolve().parents[3]
DRY = ROOT / "results" / "dryrun"
ROOF = ROOT / "results" / "roofline"


def _load(path):
    return json.loads(path.read_text()) if path.exists() else None


def dryrun_table(tag="baseline") -> str:
    rows = ["| arch | shape | mesh | peak GiB/dev | HLO GFLOPs/dev (scan-1) "
            "| collective MB/dev | compile s |",
            "|---|---|---|---|---|---|---|"]
    for arch in ARCHS:
        cfg = get_config(arch)
        for shape in shapes_for(cfg):
            for mesh in ("16x16", "2x16x16"):
                r = _load(DRY / arch / shape.name / f"{mesh}.{tag}.json")
                if r is None:
                    rows.append(f"| {arch} | {shape.name} | {mesh} | "
                                "MISSING | | | |")
                    continue
                m = r["memory"]["peak_estimate_per_device"] / 2**30
                fl = r["cost"]["flops_per_device_hlo"] / 1e9
                cb = r["collectives"]["total_bytes"] / 2**20
                rows.append(
                    f"| {arch} | {shape.name} | {mesh} | {m:.2f} | "
                    f"{fl:.1f} | {cb:.1f} | {r['times']['compile_s']} |")
        for (a, s), why in SKIPPED_CELLS.items():
            if a == arch:
                rows.append(f"| {arch} | {s} | — | {why} | | | |")
    return "\n".join(rows)


def roofline_table(tag="baseline") -> str:
    hdr = ("| arch | shape | compute ms | memory ms | collective ms | "
           "dominant | MODEL_GFLOPs/dev | useful ratio | bound ms |")
    rows = [hdr, "|---|---|---|---|---|---|---|---|---|"]
    for arch in ARCHS:
        cfg = get_config(arch)
        for shape in shapes_for(cfg):
            r = _load(ROOF / arch / shape.name / f"16x16.{tag}.json")
            if r is None:
                rows.append(f"| {arch} | {shape.name} | MISSING | | | | | | |")
                continue
            t = r["terms"]
            rows.append(
                f"| {arch} | {shape.name} | {t['compute_s']*1e3:.3f} | "
                f"{t['memory_s']*1e3:.3f} | {t['collective_s']*1e3:.3f} | "
                f"{t['dominant']} | "
                f"{r['model_flops_per_device']/1e9:.1f} | "
                f"{r['useful_flops_ratio']:.2f} | "
                f"{t['step_lower_bound_s']*1e3:.3f} |")
    return "\n".join(rows)


if __name__ == "__main__":
    print("## §Dry-run\n")
    print(dryrun_table())
    print("\n## §Roofline\n")
    print(roofline_table())
