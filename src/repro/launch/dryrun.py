import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST run before any jax import (jax locks the device
# count at first init). Everything below may import jax.

import argparse          # noqa: E402
import dataclasses       # noqa: E402
import json              # noqa: E402
import time              # noqa: E402
import traceback         # noqa: E402
from pathlib import Path # noqa: E402

import jax               # noqa: E402

from repro.configs import (  # noqa: E402
    ARCHS, SKIPPED_CELLS, get_config, get_shape, shapes_for)
from repro.launch import hlo as hlo_mod  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.sharding import named_shardings  # noqa: E402
from repro.steps import make_step  # noqa: E402

RESULTS = Path(__file__).resolve().parents[3] / "results" / "dryrun"


def lower_cell(arch: str, shape_name: str, *, multi_pod: bool,
               step_kwargs=None, cfg_override=None, save=True,
               tag="baseline"):
    """Lower + compile one (arch × shape × mesh) cell; returns the record.

    This is deliverable (e): ``.lower().compile()`` must succeed for every
    cell; memory_analysis proves fit, cost_analysis feeds §Roofline.
    """
    cfg = get_config(arch)
    if cfg_override:
        cfg = dataclasses.replace(cfg, **cfg_override)
    shape = get_shape(shape_name)
    mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()
    step = make_step(cfg, shape, mesh, **(step_kwargs or {}))
    in_sh = named_shardings(mesh, step.in_specs)
    out_sh = named_shardings(mesh, step.out_specs)
    # donate what the next step overwrites: train → state, decode → caches;
    # serving params are shared across steps and must never be donated.
    donate = {"train": (0,), "decode": (1,), "prefill": ()}[step.meta["kind"]]
    with jax.set_mesh(mesh):
        jitted = jax.jit(step.fn, in_shardings=in_sh, out_shardings=out_sh,
                         donate_argnums=donate)
        lowered = jitted.lower(*step.arg_structs)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
    ma = compiled.memory_analysis()
    ca = compiled.cost_analysis() or {}
    text = compiled.as_text()
    coll = hlo_mod.collective_stats(text)
    n_dev = mesh.devices.size
    record = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16", "devices": n_dev,
        "tag": tag,
        "memory": {
            "argument_bytes_per_device": ma.argument_size_in_bytes,
            "output_bytes_per_device": ma.output_size_in_bytes,
            "temp_bytes_per_device": ma.temp_size_in_bytes,
            "alias_bytes_per_device": ma.alias_size_in_bytes,
            "peak_estimate_per_device": (
                ma.argument_size_in_bytes + ma.output_size_in_bytes
                + ma.temp_size_in_bytes - ma.alias_size_in_bytes),
        },
        "cost": {
            "flops_per_device_hlo": ca.get("flops", 0.0),
            "bytes_accessed_per_device_hlo": ca.get("bytes accessed", 0.0),
        },
        "collectives": {
            "total_bytes": coll.total_bytes,
            "by_kind": coll.by_kind,
            "count": coll.count,
        },
        "loop_dims": step.loop_dims,
        "meta": step.meta,
        "times": {"lower_s": round(t_lower, 2),
                  "compile_s": round(t_compile, 2)},
    }
    if save:
        out = RESULTS / arch / shape_name
        out.mkdir(parents=True, exist_ok=True)
        fn = out / f"{record['mesh']}.{tag}.json"
        fn.write_text(json.dumps(record, indent=2))
        # keep a trimmed collective schedule for §Dry-run
        (out / f"{record['mesh']}.{tag}.schedule.txt").write_text(
            "\n".join(f"{k} {b} {rg}" for k, b, rg in coll.schedule[:400]))
    return record


def main():
    ap = argparse.ArgumentParser(description="multi-pod dry-run")
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="both")
    ap.add_argument("--all", action="store_true")
    args = ap.parse_args()

    cells = []
    archs = ARCHS if (args.all or not args.arch) else (args.arch,)
    for arch in archs:
        cfg = get_config(arch)
        for shape in shapes_for(cfg):
            if args.shape and shape.name != args.shape:
                continue
            cells.append((arch, shape.name))
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    failures = []
    for arch, shape_name in cells:
        for multi in meshes:
            mesh_name = "2x16x16" if multi else "16x16"
            out = RESULTS / arch / shape_name / f"{mesh_name}.baseline.json"
            if out.exists():
                print(f"[skip-cached] {arch} × {shape_name} × {mesh_name}")
                continue
            print(f"[dryrun] {arch} × {shape_name} × {mesh_name} ...",
                  flush=True)
            try:
                rec = lower_cell(arch, shape_name, multi_pod=multi)
                mem = rec["memory"]["peak_estimate_per_device"] / 2**30
                print(f"  ok: peak≈{mem:.2f} GiB/dev, "
                      f"flops={rec['cost']['flops_per_device_hlo']:.3g}, "
                      f"coll={rec['collectives']['total_bytes']:.3g}B, "
                      f"compile={rec['times']['compile_s']}s", flush=True)
            except Exception as e:  # noqa: BLE001 — report and continue
                failures.append((arch, shape_name, mesh_name, repr(e)))
                print(f"  FAIL: {e}\n{traceback.format_exc()}", flush=True)
    skipped = [f"{a} × {s}: {why}" for (a, s), why in SKIPPED_CELLS.items()]
    print("\nskipped cells (per DESIGN.md §5):")
    for s in skipped:
        print("  " + s)
    if failures:
        print(f"\n{len(failures)} FAILURES:")
        for f in failures:
            print("  ", f)
        raise SystemExit(1)
    print("\nall requested cells lowered + compiled OK")


if __name__ == "__main__":
    main()
