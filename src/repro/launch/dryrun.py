import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST run before any jax import (jax locks the device
# count at first init). Everything below may import jax.

import argparse          # noqa: E402
import dataclasses       # noqa: E402
import json              # noqa: E402
import time              # noqa: E402
import traceback         # noqa: E402
from pathlib import Path # noqa: E402

import jax               # noqa: E402

from repro.configs import (  # noqa: E402
    ARCHS, SKIPPED_CELLS, get_config, get_shape, shapes_for)
from repro.launch import hlo as hlo_mod  # noqa: E402
from repro.launch.mesh import make_production_mesh, mesh_context  # noqa: E402
from repro.sharding import named_shardings  # noqa: E402
from repro.steps import make_step  # noqa: E402

RESULTS = Path(__file__).resolve().parents[3] / "results" / "dryrun"


def lower_cell(arch: str, shape_name: str, *, multi_pod: bool,
               step_kwargs=None, cfg_override=None, save=True,
               tag="baseline"):
    """Lower + compile one (arch × shape × mesh) cell; returns the record.

    This is deliverable (e): ``.lower().compile()`` must succeed for every
    cell; memory_analysis proves fit, cost_analysis feeds §Roofline.
    """
    cfg = get_config(arch)
    if cfg_override:
        cfg = dataclasses.replace(cfg, **cfg_override)
    shape = get_shape(shape_name)
    mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()
    step = make_step(cfg, shape, mesh, **(step_kwargs or {}))
    in_sh = named_shardings(mesh, step.in_specs)
    out_sh = named_shardings(mesh, step.out_specs)
    # donate what the next step overwrites: train → state, decode → caches;
    # serving params are shared across steps and must never be donated.
    donate = {"train": (0,), "decode": (1,), "prefill": ()}[step.meta["kind"]]
    with mesh_context(mesh):
        jitted = jax.jit(step.fn, in_shardings=in_sh, out_shardings=out_sh,
                         donate_argnums=donate)
        lowered = jitted.lower(*step.arg_structs)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
    ma = compiled.memory_analysis()
    ca = compiled.cost_analysis() or {}
    text = compiled.as_text()
    coll = hlo_mod.collective_stats(text)
    n_dev = mesh.devices.size
    record = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16", "devices": n_dev,
        "tag": tag,
        "memory": {
            "argument_bytes_per_device": ma.argument_size_in_bytes,
            "output_bytes_per_device": ma.output_size_in_bytes,
            "temp_bytes_per_device": ma.temp_size_in_bytes,
            "alias_bytes_per_device": ma.alias_size_in_bytes,
            "peak_estimate_per_device": (
                ma.argument_size_in_bytes + ma.output_size_in_bytes
                + ma.temp_size_in_bytes - ma.alias_size_in_bytes),
        },
        "cost": {
            "flops_per_device_hlo": ca.get("flops", 0.0),
            "bytes_accessed_per_device_hlo": ca.get("bytes accessed", 0.0),
        },
        "collectives": {
            "total_bytes": coll.total_bytes,
            "by_kind": coll.by_kind,
            "count": coll.count,
        },
        "loop_dims": step.loop_dims,
        "meta": step.meta,
        "times": {"lower_s": round(t_lower, 2),
                  "compile_s": round(t_compile, 2)},
    }
    if save:
        out = RESULTS / arch / shape_name
        out.mkdir(parents=True, exist_ok=True)
        fn = out / f"{record['mesh']}.{tag}.json"
        fn.write_text(json.dumps(record, indent=2))
        # keep a trimmed collective schedule for §Dry-run
        (out / f"{record['mesh']}.{tag}.schedule.txt").write_text(
            "\n".join(f"{k} {b} {rg}" for k, b, rg in coll.schedule[:400]))
    return record


def run_tm_checks(*, data: int = 2, model: int = 4, n_clauses: int = 256,
                  batch: int = 16, train_batch: int = 8, save: bool = True,
                  expect_composition: str | None = None) -> dict:
    """Lower + compile the clause-sharded TM path; assert the vote HLO.

    For every registered engine: the sharded ``scores`` program must contain
    **exactly one** collective, and it must be the (B, m) vote all-reduce —
    the Massively Parallel TM contract (DESIGN.md §6). The sharded
    ``train_step`` may psum a vote per class round (+ delta reductions in
    parallel mode) but must never gather state or caches: every collective
    has to be an all-reduce.

    Backend routes (DESIGN.md §8): the packed engine is additionally lowered
    per kernel backend — under ``pallas_interpret`` the shard-local
    evaluator must *be* the Pallas kernel (``pallas_call`` in the jaxpr)
    while the program still contains only the single vote all-reduce; under
    ``xla`` no kernel call may appear.

    Ragged routes (DESIGN.md §9): ``n_clauses`` need not divide by either
    mesh axis. The sequential train record names which composition rule
    fired (``composed_even`` / ``composed_ragged`` / ``replicated``);
    ``expect_composition`` records a failure when a different rule fires —
    the CI cell pins a previously-indivisible shape onto
    ``composed_ragged`` with the collective profile unchanged
    (all-reduce-only, one vote all-reduce for scores).
    """
    import jax.numpy as jnp

    from repro.core import TMConfig, registered_engines
    from repro.core.distributed import (
        geometry, make_sharded_prepare, make_sharded_scores,
        make_sharded_train_step)
    from repro.core.engines import get_engine
    from repro.core.types import init_tm
    from repro.launch.mesh import make_host_mesh

    cfg = TMConfig(n_classes=10, n_clauses=n_clauses, n_features=196)
    mesh = make_host_mesh(data=data, model=model)
    geom = geometry(cfg, mesh)
    bundle = make_sharded_prepare(cfg, mesh)(init_tm(cfg))
    xs = jnp.zeros((batch, cfg.n_features), jnp.uint8)
    record: dict = {"mesh": f"{data}x{model}", "n_clauses": n_clauses,
                    "geometry": {"n_local": geom.n_local,
                                 "n_padded": geom.n_padded,
                                 "n_sub": geom.n_sub,
                                 "ragged_clauses": geom.ragged_clauses},
                    "engines": {}, "backend_routes": {}, "failures": []}

    for name in registered_engines():
        eng = get_engine(name)
        s = make_sharded_scores(cfg, mesh, engine=name)
        cache = (bundle.state if not eng.needs_cache
                 else bundle.caches[eng.cache_key])
        compiled = s.jitted.lower(cache, s.pol, xs).compile()
        coll = hlo_mod.collective_stats(compiled.as_text())
        ok = coll.count == 1 and set(coll.by_kind) == {"all-reduce"}
        record["engines"][name] = {
            "collective_count": coll.count, "by_kind": coll.by_kind,
            "one_vote_all_reduce": ok}
        print(f"[tm] scores/{name}: collectives={coll.by_kind} "
              f"count={coll.count} {'OK' if ok else 'FAIL'}", flush=True)
        if not ok:
            record["failures"].append(
                f"scores/{name}: expected exactly one vote all-reduce, got "
                f"{coll.by_kind} (count={coll.count})")

    # -- kernel backend routes for the packed engine ------------------------
    pcache = bundle.caches[get_engine("bitpack").cache_key]
    for backend in ("xla", "pallas_interpret"):
        cfg_b = dataclasses.replace(cfg, backend=backend)
        s = make_sharded_scores(cfg_b, mesh, engine="bitpack")
        jaxpr = str(jax.make_jaxpr(s.jitted)(pcache, s.pol, xs))
        kernel_routed = "pallas_call" in jaxpr
        coll = hlo_mod.collective_stats(
            s.jitted.lower(pcache, s.pol, xs).compile().as_text())
        one_ar = coll.count == 1 and set(coll.by_kind) == {"all-reduce"}
        want_kernel = backend != "xla"
        ok = one_ar and kernel_routed == want_kernel
        record["backend_routes"][backend] = {
            "pallas_call_in_jaxpr": kernel_routed,
            "collective_count": coll.count, "by_kind": coll.by_kind,
            "one_vote_all_reduce": one_ar}
        print(f"[tm] scores/bitpack[{backend}]: pallas_call={kernel_routed} "
              f"collectives={coll.by_kind} count={coll.count} "
              f"{'OK' if ok else 'FAIL'}", flush=True)
        if not ok:
            record["failures"].append(
                f"scores/bitpack[{backend}]: expected "
                f"{'the Pallas kernel' if want_kernel else 'the XLA body'} "
                f"with one vote all-reduce, got pallas_call={kernel_routed}, "
                f"{coll.by_kind} (count={coll.count})")

    # -- kernel backend routes for the indexed engine -----------------------
    # Matmul-form Eq. 4 (indexed_votes) must route exactly like clause_votes:
    # pallas_call in the jaxpr ⇔ a pallas backend, one vote all-reduce either
    # way. The train leg covers the second new primitive: index maintenance
    # (index_update) is the same scatter-bound batched-replay body on both
    # routes, and the step's collective profile must stay all-reduce-only
    # regardless of backend.
    icache = bundle.caches[get_engine("indexed").cache_key]
    btxs = jnp.zeros((train_batch, cfg.n_features), jnp.uint8)
    btys = jnp.zeros((train_batch,), jnp.int32)
    btmask = jnp.ones((train_batch,), bool)
    bkd = jax.random.key_data(jax.random.key(0))
    for backend in ("xla", "pallas_interpret"):
        cfg_b = dataclasses.replace(cfg, backend=backend)
        s = make_sharded_scores(cfg_b, mesh, engine="indexed")
        jaxpr = str(jax.make_jaxpr(s.jitted)(icache, s.pol, xs))
        kernel_routed = "pallas_call" in jaxpr
        coll = hlo_mod.collective_stats(
            s.jitted.lower(icache, s.pol, xs).compile().as_text())
        one_ar = coll.count == 1 and set(coll.by_kind) == {"all-reduce"}
        want_kernel = backend != "xla"
        tstep = make_sharded_train_step(cfg_b, mesh, parallel=False,
                                        max_events=1024)
        tcoll = hlo_mod.collective_stats(
            tstep.jitted.lower(bundle.state, bundle.caches, tstep.pol, btxs,
                               btys, bkd, btmask,
                               jnp.zeros((), jnp.int32)).compile().as_text())
        update_ok = set(tcoll.by_kind) <= {"all-reduce"}
        ok = one_ar and kernel_routed == want_kernel and update_ok
        record["backend_routes"][f"indexed_{backend}"] = {
            "pallas_call_in_jaxpr": kernel_routed,
            "collective_count": coll.count, "by_kind": coll.by_kind,
            "one_vote_all_reduce": one_ar,
            "train_step_all_reduce_only": update_ok,
            "train_step_by_kind": tcoll.by_kind}
        print(f"[tm] scores/indexed[{backend}]: pallas_call={kernel_routed} "
              f"collectives={coll.by_kind} count={coll.count} "
              f"train={tcoll.by_kind} {'OK' if ok else 'FAIL'}", flush=True)
        if not ok:
            record["failures"].append(
                f"scores/indexed[{backend}]: expected "
                f"{'the Pallas kernel' if want_kernel else 'the XLA body'} "
                f"with one vote all-reduce and an all-reduce-only train "
                f"step, got pallas_call={kernel_routed}, {coll.by_kind} "
                f"(count={coll.count}), train={tcoll.by_kind}")

    for parallel in (False, True):
        step = make_sharded_train_step(cfg, mesh, parallel=parallel,
                                       max_events=1024)
        txs = jnp.zeros((train_batch, cfg.n_features), jnp.uint8)
        tys = jnp.zeros((train_batch,), jnp.int32)
        tmask = jnp.ones((train_batch,), bool)
        kd = jax.random.key_data(jax.random.key(0))
        overflow0 = jnp.zeros((), jnp.int32)
        compiled = step.jitted.lower(bundle.state, bundle.caches, step.pol,
                                     txs, tys, kd, tmask, overflow0).compile()
        coll = hlo_mod.collective_stats(compiled.as_text())
        # sequential composes data×clause here (even or ragged sub-slices):
        # its clause-slice reassembly psum is an all-reduce too — the
        # contract stays "all-reduce only", never a gather of state/caches
        ok = set(coll.by_kind) <= {"all-reduce"}
        key = f"train_step_{'parallel' if parallel else 'sequential'}"
        record[key] = {"collective_count": coll.count,
                       "by_kind": coll.by_kind, "all_reduce_only": ok,
                       "composition": step.composition}
        print(f"[tm] {key}: collectives={coll.by_kind} count={coll.count} "
              f"composition={step.composition} {'OK' if ok else 'FAIL'}",
              flush=True)
        if not ok:
            record["failures"].append(
                f"{key}: feedback must stay shard-local — found "
                f"{coll.by_kind}")
        if (not parallel and expect_composition is not None
                and step.composition != expect_composition):
            record["failures"].append(
                f"{key}: expected composition rule {expect_composition!r}, "
                f"fired {step.composition!r}")

    if save:
        out = RESULTS / "tm"
        out.mkdir(parents=True, exist_ok=True)
        (out / f"{record['mesh']}.json").write_text(
            json.dumps(record, indent=2))
    return record


def run_tm_async_checks(*, k: int = 4, n_clauses: int = 256,
                        train_batch: int = 8, save: bool = True) -> dict:
    """Lower the async (stale-vote) train path; assert its collective HLO.

    The asynchronous contract (DESIGN.md §11): with ``async_votes=K`` the
    step executable contains **zero vote collectives** — the per-class-round
    psum and the per-step overflow psum are both gone — leaving only what
    state exactness requires (nothing on a clause-only mesh; the reassembly
    all-reduce under hierarchical composition; the delta all-reduce in
    batch-parallel mode). The K-step refresh is its own executable with
    **exactly one** all-reduce (votes + overflow packed together). Per mesh
    × mode the invariant pins the arithmetic: ``async static collective
    count == sync count − 3`` — the sync step carries two vote psums (one
    per class round: the target-class and the sampled-negative round) plus
    the per-step overflow psum, and async removes all three.
    """
    import jax.numpy as jnp

    from repro.core import TMConfig
    from repro.core.distributed import (
        make_sharded_prepare, make_sharded_train_step, make_vote_refresh)
    from repro.core.types import init_tm
    from repro.launch.mesh import make_host_mesh

    cfg = TMConfig(n_classes=10, n_clauses=n_clauses, n_features=196)
    record: dict = {"k": k, "n_clauses": n_clauses, "cells": {},
                    "failures": []}
    txs = jnp.zeros((train_batch, cfg.n_features), jnp.uint8)
    tys = jnp.zeros((train_batch,), jnp.int32)
    tmask = jnp.ones((train_batch,), bool)
    kd = jax.random.key_data(jax.random.key(0))

    # (mesh, mode) cells × the in-step collective count async may keep:
    # clause-only sequential has nothing left; composition keeps its
    # reassembly all-reduce; batch-parallel keeps its delta all-reduce.
    cells = [("1x4", dict(data=1, model=4), False, 0),
             ("2x4", dict(data=2, model=4), False, 1),
             ("2x4", dict(data=2, model=4), True, 1)]
    for mesh_name, mesh_kw, parallel, allowed in cells:
        mesh = make_host_mesh(**mesh_kw)
        bundle = make_sharded_prepare(cfg, mesh, async_votes=k)(init_tm(cfg))
        mode = "parallel" if parallel else "sequential"
        key = f"{mesh_name}/{mode}"

        counts = {}
        for tag, async_votes in (("sync", 0), ("async", k)):
            step = make_sharded_train_step(
                cfg, mesh, parallel=parallel, max_events=1024,
                async_votes=async_votes)
            args = ((bundle.state, bundle.caches, step.pol, bundle.vote_acc,
                     txs, tys, kd, tmask) if async_votes else
                    (bundle.state, bundle.caches, step.pol, txs, tys, kd,
                     tmask, jnp.zeros((), jnp.int32)))
            coll = hlo_mod.collective_stats(
                step.jitted.lower(*args).compile().as_text())
            counts[tag] = coll
        refresh = make_vote_refresh(cfg, mesh, parallel=parallel)
        rcoll = hlo_mod.collective_stats(
            refresh.jitted.lower(bundle.vote_acc,
                                 jnp.zeros((), jnp.int32)).compile().as_text())

        a, s = counts["async"], counts["sync"]
        ok_step = (a.count == allowed and set(a.by_kind) <= {"all-reduce"})
        ok_delta = a.count == s.count - 3
        ok_refresh = (rcoll.count == 1
                      and set(rcoll.by_kind) == {"all-reduce"})
        record["cells"][key] = {
            "composition": step.composition,
            "sync_collectives": s.by_kind, "sync_count": s.count,
            "async_collectives": a.by_kind, "async_count": a.count,
            "async_allowed": allowed,
            "refresh_collectives": rcoll.by_kind,
            "refresh_count": rcoll.count,
            "zero_vote_collectives": ok_step,
            "removed_vote_collectives": ok_delta,
            "one_refresh_all_reduce": ok_refresh}
        print(f"[tm-async] {key} ({step.composition}): "
              f"sync={s.count} async={a.count} (allowed {allowed}) "
              f"refresh={rcoll.count} "
              f"{'OK' if ok_step and ok_delta and ok_refresh else 'FAIL'}",
              flush=True)
        if not ok_step:
            record["failures"].append(
                f"{key}: async step must keep <= {allowed} all-reduce(s), "
                f"got {a.by_kind} (count={a.count})")
        if not ok_delta:
            record["failures"].append(
                f"{key}: async must remove exactly the two per-round vote "
                f"psums + the overflow psum (sync {s.count} -> async "
                f"{a.count}, expected {s.count - 3})")
        if not ok_refresh:
            record["failures"].append(
                f"{key}: refresh must be exactly one batched all-reduce, "
                f"got {rcoll.by_kind} (count={rcoll.count})")

    if save:
        out = RESULTS / "tm"
        out.mkdir(parents=True, exist_ok=True)
        (out / "async.json").write_text(json.dumps(record, indent=2))
    return record


def main():
    ap = argparse.ArgumentParser(description="multi-pod dry-run")
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="both")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--tm", action="store_true",
                    help="clause-sharded TM lowering checks (every engine; "
                         "asserts the single vote all-reduce)")
    ap.add_argument("--async-votes", action="store_true",
                    help="with --tm: also check the async stale-vote train "
                         "path (zero in-step vote collectives, one "
                         "all-reduce per K-step refresh)")
    args = ap.parse_args()

    if args.tm:
        # the PR-3 even cell + a previously-indivisible ragged cell
        # (n_clauses=128 over 3 clause shards × 2 data ranks — DESIGN.md §9):
        # both must lower to the same collective profile, and the ragged one
        # must fire the composed_ragged rule, not the replication fallback
        records = [
            run_tm_checks(expect_composition="composed_even"),
            run_tm_checks(data=2, model=3, n_clauses=128,
                          expect_composition="composed_ragged"),
        ]
        if args.async_votes:
            records.append(run_tm_async_checks())
        failures = [f for r in records for f in r["failures"]]
        if failures:
            print(f"\n{len(failures)} TM FAILURES:")
            for f in failures:
                print("  ", f)
            raise SystemExit(1)
        print("\nTM sharded lowering: all engines OK "
              "(one vote all-reduce; shard-local feedback; "
              "composition rules: "
              + ", ".join(f"{r['mesh']}→"
                          f"{r['train_step_sequential']['composition']}"
                          for r in records if "train_step_sequential" in r)
              + ("; async stale-vote route OK" if args.async_votes else "")
              + ")")
        return

    cells = []
    archs = ARCHS if (args.all or not args.arch) else (args.arch,)
    for arch in archs:
        cfg = get_config(arch)
        for shape in shapes_for(cfg):
            if args.shape and shape.name != args.shape:
                continue
            cells.append((arch, shape.name))
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    failures = []
    for arch, shape_name in cells:
        for multi in meshes:
            mesh_name = "2x16x16" if multi else "16x16"
            out = RESULTS / arch / shape_name / f"{mesh_name}.baseline.json"
            if out.exists():
                print(f"[skip-cached] {arch} × {shape_name} × {mesh_name}")
                continue
            print(f"[dryrun] {arch} × {shape_name} × {mesh_name} ...",
                  flush=True)
            try:
                rec = lower_cell(arch, shape_name, multi_pod=multi)
                mem = rec["memory"]["peak_estimate_per_device"] / 2**30
                print(f"  ok: peak≈{mem:.2f} GiB/dev, "
                      f"flops={rec['cost']['flops_per_device_hlo']:.3g}, "
                      f"coll={rec['collectives']['total_bytes']:.3g}B, "
                      f"compile={rec['times']['compile_s']}s", flush=True)
            except Exception as e:  # noqa: BLE001 — report and continue
                failures.append((arch, shape_name, mesh_name, repr(e)))
                print(f"  FAIL: {e}\n{traceback.format_exc()}", flush=True)
    skipped = [f"{a} × {s}: {why}" for (a, s), why in SKIPPED_CELLS.items()]
    print("\nskipped cells (per DESIGN.md §5):")
    for s in skipped:
        print("  " + s)
    if failures:
        print(f"\n{len(failures)} FAILURES:")
        for f in failures:
            print("  ", f)
        raise SystemExit(1)
    print("\nall requested cells lowered + compiled OK")


if __name__ == "__main__":
    main()
