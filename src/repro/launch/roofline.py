"""Roofline analysis: three terms per (arch × shape × mesh) cell.

Hardware (TPU v5e, per brief): 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI.

    compute term    = FLOPs_per_device / 197e12
    memory term     = HBM_bytes_per_device / 819e9
    collective term = collective_bytes_per_device / 50e9

XLA's cost model counts while-loop bodies ONCE (verified; DESIGN.md §6), so
FLOPs/bytes/collectives are reconstructed from *probe* compiles that unroll
every loop:

  probes (train): (M=1, L=1), (M=2, L=1), (M=1, L=2)  [+ enc dim for encdec]
  model:  cost(M, L…) = c0 + M · (c1 + Σ_d L_d · c2_d)
  solve:  c2_d = f_d − f_base;  c0 = 2·f_base − f_M2;  c1 = f_base − c0 − Σ c2_d
  full:   c0 + M_full · (c1 + Σ_d L_d_full · c2_d)

Probe configs additionally run single-chunk (kv_block=seq, rwkv/rnn chunk =
seq, dense attention) so no inner scan hides cost. Chunk bookkeeping deltas
vs the production chunked program are O(chunks) adds — negligible.

MODEL_FLOPS (useful-work yardstick): 6·N·D (train) / 2·N·D (inference),
N = params (dense) or active params (MoE), D = tokens processed.
"""
from __future__ import annotations

import dataclasses
import json
from pathlib import Path

import jax

from repro.configs import get_config, get_shape
from repro.configs.base import ModelConfig, ShapeSpec
from repro.launch import hlo as hlo_mod
from repro.launch.mesh import mesh_context, make_production_mesh
from repro.sharding import named_shardings
from repro.steps import make_step

PEAK_FLOPS = 197e12      # bf16 / chip
HBM_BW = 819e9           # B/s / chip
ICI_BW = 50e9            # B/s / link

RESULTS = Path(__file__).resolve().parents[3] / "results" / "roofline"


# ---------------------------------------------------------------------------
# Probe machinery
# ---------------------------------------------------------------------------


def _probe_cfg(cfg: ModelConfig, shape: ShapeSpec, layer_overrides: dict):
    """Unrolled probe config with the given layer counts.

    Attention: kv_block=seq (single flash iteration ⇒ exact count; the
    score bytes touched are the same as production's blockwise form).
    Recurrences: production-style chunking with the chunk loop UNROLLED
    (use_scan=False threads through models/recurrence.py) — a full-seq
    single chunk would hand the associative scan T-length log-depth temps
    and inflate the memory term ~20× (observed on rwkv6 train probes).
    Chunk sizes are raised so ≤32 chunk bodies unroll per layer.
    """
    upd: dict = dict(
        use_scan=False,
        remat=False,                     # probes measure true per-layer cost;
        # remat recompute shows up in the full-compile cross-check instead.
        kv_block=shape.seq_len,
        dense_attn_max=max(cfg.dense_attn_max, shape.seq_len),
        rwkv_chunk=max(cfg.rwkv_chunk, -(-shape.seq_len // 32)),
        rnn_chunk=max(cfg.rnn_chunk, -(-shape.seq_len // 32)),
    )
    if cfg.family == "hybrid":
        pat = cfg.pattern or ("rec", "rec", "attn")
        g = layer_overrides.get("layers", 1)
        upd["n_layers"] = len(pat) * g + cfg.n_layers % len(pat)
    else:
        upd["n_layers"] = layer_overrides.get("layers", 1)
    if cfg.family == "encdec":
        upd["n_enc_layers"] = layer_overrides.get("enc_layers", 1)
    return dataclasses.replace(cfg, **upd)


def _measure(cfg, shape, mesh, *, microbatches, kind):
    """Lower+compile one probe; return dict of cost scalars (per device)."""
    kw = {}
    if kind == "train":
        kw["microbatches"] = microbatches
        kw["compress"] = "none"
    shape_p = shape
    if kind == "train":
        # probe batch = microbatch_size × M so per-microbatch work matches
        mb_size = shape.global_batch // 8  # production microbatch count = 8
        shape_p = dataclasses.replace(
            shape, global_batch=mb_size * microbatches)
    step = make_step(cfg, shape_p, mesh, **kw)
    in_sh = named_shardings(mesh, step.in_specs)
    out_sh = named_shardings(mesh, step.out_specs)
    with mesh_context(mesh):
        compiled = (
            jax.jit(step.fn, in_shardings=in_sh, out_shardings=out_sh)
            .lower(*step.arg_structs).compile())
    ca = compiled.cost_analysis() or {}
    coll = hlo_mod.collective_stats(compiled.as_text())
    return {
        "flops": float(ca.get("flops", 0.0)),
        "bytes": float(ca.get("bytes accessed", 0.0)),
        "coll_bytes": float(coll.total_bytes),
    }


def probe_costs(arch: str, shape_name: str, *, multi_pod=False,
                cfg_override=None, microbatches_full=8, verbose=True):
    """Run the probe set and reconstruct full-program costs per device."""
    cfg = get_config(arch)
    if cfg_override:
        cfg = dataclasses.replace(cfg, **cfg_override)
    shape = get_shape(shape_name)
    mesh = make_production_mesh(multi_pod=multi_pod)
    kind = shape.kind

    layer_dims = ["layers"] + (["enc_layers"] if cfg.family == "encdec"
                               else [])
    full_counts = {"layers": (cfg.n_layers // len(cfg.pattern or (1,))
                              if cfg.family == "hybrid" else cfg.n_layers)}
    if cfg.family == "hybrid":
        pat = cfg.pattern or ("rec", "rec", "attn")
        full_counts["layers"] = cfg.n_layers // len(pat)
    if cfg.family == "encdec":
        full_counts["enc_layers"] = cfg.n_enc_layers

    base_cfg = _probe_cfg(cfg, shape, {d: 1 for d in layer_dims})
    f_base = _measure(base_cfg, shape, mesh, microbatches=1, kind=kind)
    if verbose:
        print(f"  probe base: {f_base}", flush=True)

    c2 = {}
    for d in layer_dims:
        ov = {dd: (2 if dd == d else 1) for dd in layer_dims}
        f_d = _measure(_probe_cfg(cfg, shape, ov), shape, mesh,
                       microbatches=1, kind=kind)
        c2[d] = {k: f_d[k] - f_base[k] for k in f_base}
        if verbose:
            print(f"  probe {d}=2: {f_d}", flush=True)

    if kind == "train":
        f_m2 = _measure(base_cfg, shape, mesh, microbatches=2, kind=kind)
        if verbose:
            print(f"  probe M=2: {f_m2}", flush=True)
        c0 = {k: 2 * f_base[k] - f_m2[k] for k in f_base}
        c1 = {k: f_base[k] - c0[k] - sum(c2[d][k] for d in layer_dims)
              for k in f_base}
        m_full = microbatches_full
    else:
        c0 = {k: f_base[k] - sum(c2[d][k] for d in layer_dims)
              for k in f_base}
        c1 = {k: 0.0 for k in f_base}
        m_full = 1

    total = {
        k: c0[k] + m_full * (c1[k] + sum(
            full_counts[d] * c2[d][k] for d in layer_dims))
        for k in f_base
    }
    return {
        "per_device": total,
        "probe_coeffs": {"c0": c0, "c1": c1,
                         "c2": c2, "m_full": m_full,
                         "full_counts": full_counts},
    }


# ---------------------------------------------------------------------------
# Analytic MODEL_FLOPS
# ---------------------------------------------------------------------------


def model_flops(cfg: ModelConfig, shape: ShapeSpec) -> float:
    n = (cfg.active_param_count() if cfg.family == "moe"
         else cfg.param_count())
    if shape.kind == "train":
        return 6.0 * n * shape.tokens
    if shape.kind == "prefill":
        return 2.0 * n * shape.tokens
    return 2.0 * n * shape.global_batch  # decode: one token per sequence


# ---------------------------------------------------------------------------
# Report assembly
# ---------------------------------------------------------------------------


def roofline_terms(per_device: dict) -> dict:
    comp = per_device["flops"] / PEAK_FLOPS
    mem = per_device["bytes"] / HBM_BW
    coll = per_device["coll_bytes"] / ICI_BW
    dom = max(("compute", comp), ("memory", mem), ("collective", coll),
              key=lambda kv: kv[1])[0]
    return {
        "compute_s": comp, "memory_s": mem, "collective_s": coll,
        "dominant": dom,
        "step_lower_bound_s": max(comp, mem, coll),
    }


def analyze_cell(arch: str, shape_name: str, *, multi_pod=False,
                 cfg_override=None, tag="baseline", save=True, verbose=True):
    cfg = get_config(arch)
    if cfg_override:
        cfg = dataclasses.replace(cfg, **cfg_override)
    shape = get_shape(shape_name)
    n_dev = 512 if multi_pod else 256
    costs = probe_costs(arch, shape_name, multi_pod=multi_pod,
                        cfg_override=cfg_override, verbose=verbose)
    terms = roofline_terms(costs["per_device"])
    mf = model_flops(cfg, shape)
    mf_dev = mf / n_dev
    useful = mf_dev / max(costs["per_device"]["flops"], 1e-9)
    record = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "tag": tag,
        "per_device": costs["per_device"],
        "terms": terms,
        "model_flops_total": mf,
        "model_flops_per_device": mf_dev,
        "useful_flops_ratio": useful,
        "roofline_fraction": min(1.0, useful) * (
            terms["compute_s"] / max(terms["step_lower_bound_s"], 1e-30)),
        "probe_coeffs": costs["probe_coeffs"],
    }
    if save:
        out = RESULTS / arch / shape_name
        out.mkdir(parents=True, exist_ok=True)
        (out / f"{record['mesh']}.{tag}.json").write_text(
            json.dumps(record, indent=2))
    return record


def main():
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--tag", default="baseline")
    args = ap.parse_args()
    rec = analyze_cell(args.arch, args.shape, multi_pod=args.multi_pod,
                       tag=args.tag)
    print(json.dumps({k: v for k, v in rec.items()
                      if k != "probe_coeffs"}, indent=2))


if __name__ == "__main__":
    main()
