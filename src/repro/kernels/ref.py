"""Pure-jnp oracles for the Pallas kernels (no Pallas, no bit tricks)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def clause_votes_ref(
    include: jax.Array,  # (m, n, 2o) bool
    lit: jax.Array,      # (B, 2o) {0,1}
) -> jax.Array:
    """(B, m) int32 polarity-signed votes; empty clauses count as true."""
    m, n, L = include.shape
    false_lit = (1 - lit).astype(jnp.float32)
    counts = jnp.einsum("bk,mnk->bmn", false_lit, include.astype(jnp.float32))
    out = counts < 0.5                                   # (B, m, n) true/false
    sign = jnp.where(jnp.arange(n) < n // 2, 1, -1)
    return jnp.einsum("bmn,n->bm", out.astype(jnp.int32), sign)


def clause_outputs_ref(include: jax.Array, lit: jax.Array) -> jax.Array:
    """(B, m, n) int8 clause outputs; empty clauses → 1."""
    false_lit = (1 - lit).astype(jnp.float32)
    counts = jnp.einsum("bk,mnk->bmn", false_lit, include.astype(jnp.float32))
    return (counts < 0.5).astype(jnp.int8)


def ta_update_ref(
    ta_row: jax.Array,       # (n, 2o) int16
    lit: jax.Array,          # (2o,)
    clause_out: jax.Array,   # (n,)
    gets_type_i: jax.Array,  # (n,) bool
    active: jax.Array,       # (n,) bool
    uniforms: jax.Array,     # (n, 2o)
    *,
    n_states: int,
    s: float,
    boost_true_positive: bool = False,
) -> jax.Array:
    include = ta_row > n_states
    inv_s = 1.0 / s
    p_reward = 1.0 if boost_true_positive else 1.0 - inv_s
    c1 = (clause_out == 1)[:, None]
    l1 = (lit == 1)[None, :]
    reward = c1 & l1 & (uniforms < p_reward)
    penalty = ((c1 & ~l1) | ~c1) & (uniforms < inv_s)
    d1 = reward.astype(jnp.int16) - penalty.astype(jnp.int16)
    d2 = (c1 & ~l1 & ~include).astype(jnp.int16)
    act = active.astype(bool)[:, None]
    t1 = gets_type_i.astype(bool)[:, None]
    delta = jnp.where(act & t1, d1, jnp.where(act & ~t1, d2, 0))
    return jnp.clip(ta_row + delta, 1, 2 * n_states).astype(jnp.int16)
