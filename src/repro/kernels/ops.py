"""Public jit'd wrappers around the TM kernel primitives.

Thin conveniences over the kernel backend registry (``kernels/backend.py``):
each wrapper resolves its primitive at the *kernel-forcing* mode by default
(``backend.pallas_mode()`` — compiled Pallas on TPU, the interpreter on CPU
containers), so these are the entry points that always exercise the kernel
bodies (tests, benchmarks on TPU). Pass ``backend='xla'`` (or any registry
backend string) to override. The wrappers own the packing step so callers
deal in TM-native tensors.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.bitpack import pack_bits, packed_literals
from repro.core.types import TMConfig, TMState, clause_polarity, include_mask
from repro.kernels import backend as kbackend


def pack_include(cfg: TMConfig, state: TMState) -> jax.Array:
    """(m, n, 2o) include mask → (m, n, W) uint32."""
    return pack_bits(include_mask(cfg, state).astype(jnp.uint8))


def _mode(backend: str | None) -> str:
    return kbackend.pallas_mode() if backend is None else backend


@functools.partial(jax.jit, static_argnames=("backend",))
def tm_votes_packed(
    include_packed: jax.Array, x: jax.Array, *, backend: str | None = None
) -> jax.Array:
    """(m, n, W) packed includes + (B, o) inputs → (B, m) votes.

    Cache-taking variant for the engine registry (core/engines.py): the
    packed include words are maintained incrementally across learning steps,
    so the kernel wrapper never repacks the full include mask per call.
    """
    votes = kbackend.resolve("clause_votes", _mode(backend))
    n = include_packed.shape[1]
    pol = jnp.where(jnp.arange(n) < n // 2, 1, -1).astype(jnp.int32)
    return votes(include_packed, packed_literals(x), pol)


@functools.partial(jax.jit, static_argnames=("cfg", "backend"))
def tm_votes(
    cfg: TMConfig, state: TMState, x: jax.Array, *, backend: str | None = None
) -> jax.Array:
    """(B, o) inputs → (B, m) votes via the fused eval+vote primitive."""
    votes = kbackend.resolve("clause_votes", _mode(backend))
    return votes(pack_include(cfg, state), packed_literals(x),
                 clause_polarity(cfg))


@functools.partial(jax.jit, static_argnames=("cfg", "backend"))
def tm_predict(
    cfg: TMConfig, state: TMState, x: jax.Array, *, backend: str | None = None
) -> jax.Array:
    return jnp.argmax(tm_votes(cfg, state, x, backend=backend), axis=-1)


@functools.partial(jax.jit, static_argnames=("cfg", "backend"))
def tm_clause_outputs(
    cfg: TMConfig, state: TMState, x: jax.Array, *, backend: str | None = None
) -> jax.Array:
    """(B, o) → (B, m, n) int8 clause outputs (learning semantics)."""
    outputs = kbackend.resolve("clause_outputs", _mode(backend))
    return outputs(pack_include(cfg, state), packed_literals(x))


def tm_ta_update(
    cfg: TMConfig,
    ta_row: jax.Array,
    lit: jax.Array,
    clause_out: jax.Array,
    gets_type_i: jax.Array,
    active: jax.Array,
    uniforms: jax.Array,
    *,
    backend: str | None = None,
) -> jax.Array:
    """Kernel-backed Type I/II feedback for one class row."""
    update = kbackend.resolve("ta_update", _mode(backend))
    return update(
        ta_row, lit, clause_out, gets_type_i, active, uniforms,
        n_states=cfg.n_states, s=cfg.s,
        boost_true_positive=cfg.boost_true_positive,
    )
