"""Public jit'd wrappers around the Pallas TM kernels.

``interpret=True`` (default on this CPU container) executes kernel bodies in
Python via the Pallas interpreter; on a real TPU pass ``interpret=False``.
The wrappers own the packing step so callers deal in TM-native tensors.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.bitpack import pack_bits, packed_literals
from repro.core.types import TMConfig, TMState, include_mask
from repro.kernels import clause_eval, ta_update as ta_update_mod


def pack_include(cfg: TMConfig, state: TMState) -> jax.Array:
    """(m, n, 2o) include mask → (m, n, W) uint32."""
    return pack_bits(include_mask(cfg, state).astype(jnp.uint8))


@functools.partial(jax.jit, static_argnames=("interpret",))
def tm_votes_packed(
    include_packed: jax.Array, x: jax.Array, *, interpret: bool = True
) -> jax.Array:
    """(m, n, W) packed includes + (B, o) inputs → (B, m) votes.

    Cache-taking variant for the engine registry (core/engines.py): the
    packed include words are maintained incrementally across learning steps,
    so the kernel wrapper never repacks the full include mask per call.
    """
    lit = packed_literals(x)
    return clause_eval.clause_votes_packed(include_packed, lit,
                                           interpret=interpret)


@functools.partial(jax.jit, static_argnames=("cfg", "interpret"))
def tm_votes(
    cfg: TMConfig, state: TMState, x: jax.Array, *, interpret: bool = True
) -> jax.Array:
    """(B, o) inputs → (B, m) votes via the fused Pallas kernel."""
    inc = pack_include(cfg, state)
    return tm_votes_packed(inc, x, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("cfg", "interpret"))
def tm_predict(
    cfg: TMConfig, state: TMState, x: jax.Array, *, interpret: bool = True
) -> jax.Array:
    return jnp.argmax(tm_votes(cfg, state, x, interpret=interpret), axis=-1)


@functools.partial(jax.jit, static_argnames=("cfg", "interpret"))
def tm_clause_outputs(
    cfg: TMConfig, state: TMState, x: jax.Array, *, interpret: bool = True
) -> jax.Array:
    """(B, o) → (B, m, n) int8 clause outputs (learning semantics)."""
    inc = pack_include(cfg, state)
    lit = packed_literals(x)
    return clause_eval.clause_outputs_packed(inc, lit, interpret=interpret)


def tm_ta_update(
    cfg: TMConfig,
    ta_row: jax.Array,
    lit: jax.Array,
    clause_out: jax.Array,
    gets_type_i: jax.Array,
    active: jax.Array,
    uniforms: jax.Array,
    *,
    interpret: bool = True,
) -> jax.Array:
    """Kernel-backed Type I/II feedback for one class row."""
    return ta_update_mod.ta_update(
        ta_row, lit, clause_out, gets_type_i, active, uniforms,
        n_states=cfg.n_states, s=cfg.s,
        boost_true_positive=cfg.boost_true_positive, interpret=interpret,
    )
