"""Pallas TPU kernels for the TM hot spots (validated via interpret mode)."""
