"""Kernel backend registry: one declaration per TM primitive, many bodies.

The engines (core/engines.py) and the learning round (core/tm.py) used to
hard-wire *which* implementation of each hot primitive they ran — the Pallas
``bitpack`` engine carried an ``interpret`` constructor flag, ``bitpack_xla``
duplicated it wholesale, and training always took the XLA body. This module
makes the choice declarative instead: every TM primitive is registered once
with

  * an **XLA reference implementation** (the bit-exact semantics oracle,
    always executable),
  * a **Pallas implementation** (the TPU kernel; ``interpret=`` runs its body
    through the Pallas interpreter on hostless CI),
  * a **clause-axis partitioning contract** — how the primitive's operands
    and result partition over the mesh ``model`` (clause) axis, and whether
    the result is a partial sum completed by one psum (the vote all-reduce).

Callers resolve ``backend='auto'|'xla'|'pallas'|'pallas_interpret'`` —
threaded from ``TMConfig.backend`` / ``Topology.backend`` through
``TMSession`` — into a concrete callable via :func:`resolve`. ``auto``
resolves to Pallas on TPU, to whatever the ``REPRO_TM_BACKEND`` environment
override names (a hands-off hook for forcing e.g. interpret mode on a whole
process), and to XLA otherwise, so the same config runs the fused kernels
on hardware that has them and the reference bodies everywhere else. The CI
gates pass explicit backends instead (``tm_serve --backend
pallas_interpret``, the dryrun route checks, the benchmark sweep).

The partitioning contract is the *declared* form of how the sharded layer
wires each primitive: a clause shard calls the same resolved callable on
its local slice (local include words, local ±1 polarity), and
``vote_reduce`` records that exactly one (B, m) psum over the clause axis
completes the result — the Massively Parallel TM contract. The wiring
itself lives in ``core/distributed.py``/``core/engines.py``;
tests/test_kernel_backends.py pins the declarations equal to it (so the
contract cannot drift from the code), and ``launch/dryrun.py --tm``
asserts the lowered collective profile per backend.

Primitives registered at import: ``clause_votes``, ``clause_outputs``,
``ta_update``, ``indexed_votes``, ``index_update``.
"""
from __future__ import annotations

import dataclasses
import functools
import os
from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.kernels import clause_eval, indexed, ta_update as ta_update_mod

BACKENDS = ("auto", "xla", "pallas", "pallas_interpret")

# Mesh axis name the clause dimension shards over — must match
# core/engines.py's CLAUSE_AXIS (duplicated here to keep kernels/ free of
# core/ imports; pinned equal by tests/test_kernel_backends.py).
CLAUSE_AXIS = "model"


@dataclasses.dataclass(frozen=True)
class ClausePartitioning:
    """Clause-axis contract of one primitive under shard_map.

    ``in_specs``/``out_spec`` — PartitionSpecs of the positional operands /
    result over ``CLAUSE_AXIS`` (batch axes intentionally unnamed: the specs
    describe only how the *clause* dimension tiles).
    ``vote_reduce`` — True when shard-local results are partial sums and one
    psum over ``CLAUSE_AXIS`` yields the global result (the single (B, m)
    vote all-reduce); False when the primitive is clause-elementwise and
    needs no collective at all.
    ``clause_padding`` — how the primitive stays correct when its clause
    rows carry *padding* (the ragged geometry of DESIGN.md §9 pads the
    clause axis to ``clause_shards·⌈n/clause_shards⌉`` rows, and sequential
    data×clause composition pads each shard's sub-slices again):

      * ``'zero_polarity'`` — a padding row's ±1 polarity operand is 0, so
        its contribution to the partial vote sum is identically zero
        whatever the row evaluates to. No masking needed inside the body.
      * ``'masked_active'``  — a padding row's ``active`` gate operand is
        False, so both feedback branches apply a zero delta and the row
        passes through bit-identically (the "zero update mask").
      * ``'caller_sliced'``  — the primitive computes padding rows like any
        other; the caller owns discarding them (reassembly slice / vote
        weighting downstream).

    The sharded wiring (``core/distributed.py``) realises exactly these
    conventions — zero-padded polarity, the ``clause_mask``-gated update,
    the reassembly slice — and tests/test_kernel_backends.py pins the
    declarations equal to it.
    """

    in_specs: tuple
    out_spec: object
    vote_reduce: bool = False
    clause_padding: str = "caller_sliced"


@dataclasses.dataclass(frozen=True)
class Primitive:
    """One TM primitive: two bodies + the clause-axis contract."""

    name: str
    xla: Callable
    pallas: Callable  # must accept an ``interpret=`` keyword
    partitioning: ClausePartitioning


_PRIMITIVES: dict[str, Primitive] = {}


def register_primitive(prim: Primitive) -> Primitive:
    """Add a primitive to the registry (idempotent per name)."""
    if not prim.name:
        raise ValueError("primitive must set a non-empty name")
    _PRIMITIVES[prim.name] = prim
    return prim


def get_primitive(name: str) -> Primitive:
    """Look up a registered primitive by name (KeyError lists what exists)."""
    try:
        return _PRIMITIVES[name]
    except KeyError:
        raise KeyError(
            f"unknown TM primitive {name!r}; registered: "
            f"{registered_primitives()}") from None


def registered_primitives() -> tuple[str, ...]:
    """Registered primitive names, registration order."""
    return tuple(_PRIMITIVES)


def resolve_backend(backend: str = "auto") -> str:
    """``backend`` string → concrete mode (never ``'auto'``).

    Resolution order for ``'auto'``: the ``REPRO_TM_BACKEND`` environment
    override when set (forces e.g. ``pallas_interpret`` on a process that
    cannot pass explicit backend strings), else ``'pallas'`` on TPU, else
    ``'xla'``. Set the override before anything traces: jit caches key on
    the config string, not the resolved mode.
    """
    if backend not in BACKENDS:
        raise ValueError(
            f"unknown kernel backend {backend!r}; one of {BACKENDS}")
    if backend != "auto":
        return backend
    env = os.environ.get("REPRO_TM_BACKEND", "")
    if env:
        if env not in BACKENDS or env == "auto":
            raise ValueError(
                f"REPRO_TM_BACKEND={env!r} must be a concrete backend "
                f"(one of {BACKENDS[1:]})")
        return env
    return "pallas" if jax.default_backend() == "tpu" else "xla"


def pallas_mode() -> str:
    """The kernel-forcing mode for this host: compiled on TPU, interpreted
    elsewhere. What ``kernels/ops.py`` wrappers (and kernel tests) default
    to — unlike ``auto``, never falls back to XLA."""
    return "pallas" if jax.default_backend() == "tpu" else "pallas_interpret"


def resolve(name: str, backend: str = "auto") -> Callable:
    """Primitive name + backend string → concrete callable.

    The Pallas body comes back with ``interpret`` already bound, so call
    sites are backend-agnostic: ``resolve('clause_votes', cfg.backend)(...)``.
    """
    prim = get_primitive(name)
    mode = resolve_backend(backend)
    if mode == "xla":
        return prim.xla
    return functools.partial(prim.pallas,
                             interpret=(mode == "pallas_interpret"))


# ---------------------------------------------------------------------------
# XLA reference bodies (bit-exact semantics of the kernels, pure jnp)
# ---------------------------------------------------------------------------


def _clause_votes_xla(include_packed: jax.Array, lit_packed: jax.Array,
                      pol: jax.Array) -> jax.Array:
    """(m, n, W) packed includes + (B, W) packed literals + (n,) ±1 polarity
    → (B, m) int32 polarity-signed vote sums (Eq. 3/4 semantics: a clause is
    true iff no included literal is violated; empty clauses count true)."""
    viol = include_packed[None] & (~lit_packed)[:, None, None]   # (B,m,n,W)
    out = ~jnp.any(viol != 0, axis=-1)                           # (B,m,n)
    return jnp.einsum("bmn,n->bm", out.astype(jnp.int32),
                      pol.astype(jnp.int32))


def _clause_outputs_xla(include_packed: jax.Array,
                        lit_packed: jax.Array) -> jax.Array:
    """(m, n, W) packed includes + (B, W) packed literals → (B, m, n) int8
    clause outputs (learning semantics: empty clauses → 1)."""
    viol = include_packed[None] & (~lit_packed)[:, None, None]
    return (~jnp.any(viol != 0, axis=-1)).astype(jnp.int8)


def _ta_update_xla(
    ta_row: jax.Array,       # (n, 2o) int16
    lit: jax.Array,          # (2o,)
    clause_out: jax.Array,   # (n,)
    gets_type_i: jax.Array,  # (n,) bool
    active: jax.Array,       # (n,) bool
    uniforms: jax.Array,     # (n, 2o) float32
    *,
    n_states: int,
    s: float,
    boost_true_positive: bool = False,
) -> jax.Array:
    """Type I / Type II feedback application, (n, 2o) int16 → int16.

    The reference body the Pallas ``ta_update`` kernel is pinned against
    (kernels/ref.py holds the numpy twin used by the oracle tests).
    """
    include = ta_row > n_states
    inv_s = 1.0 / s
    p_reward = 1.0 if boost_true_positive else 1.0 - inv_s
    c1 = (clause_out == 1)[:, None]
    l1 = (lit == 1)[None, :]
    reward = c1 & l1 & (uniforms < p_reward)
    penalty = ((c1 & ~l1) | ~c1) & (uniforms < inv_s)
    d1 = reward.astype(jnp.int16) - penalty.astype(jnp.int16)
    d2 = (c1 & ~l1 & ~include).astype(jnp.int16)
    act = active.astype(bool)[:, None]
    t1 = gets_type_i.astype(bool)[:, None]
    delta = jnp.where(act & t1, d1, jnp.where(act & ~t1, d2, 0))
    return jnp.clip(ta_row + delta, 1, 2 * n_states).astype(jnp.int16)


# ---------------------------------------------------------------------------
# Registrations
# ---------------------------------------------------------------------------

# Fused eval + vote: shard-local partial sums, ONE psum completes them.
register_primitive(Primitive(
    name="clause_votes",
    xla=_clause_votes_xla,
    pallas=clause_eval.clause_votes_packed,
    partitioning=ClausePartitioning(
        in_specs=(P(None, CLAUSE_AXIS, None),   # include words (m, n, W)
                  P(None, None),                # packed literals (B, W)
                  P(CLAUSE_AXIS)),              # polarity (n,)
        out_spec=P(None, None),                 # (B, m) partial votes
        vote_reduce=True,
        clause_padding="zero_polarity",         # sign-0 rows are inert
    ),
))

# Raw clause outputs (training / diagnostics): clause axis tiles through.
register_primitive(Primitive(
    name="clause_outputs",
    xla=_clause_outputs_xla,
    pallas=clause_eval.clause_outputs_packed,
    partitioning=ClausePartitioning(
        in_specs=(P(None, CLAUSE_AXIS, None),
                  P(None, None)),
        out_spec=P(None, None, CLAUSE_AXIS),    # (B, m, n)
        vote_reduce=False,
        clause_padding="caller_sliced",         # outputs feed a 0-pol vote
    ),
))

# Matmul-form Eq. 4 over the falsification index's membership mask
# (pos != NA): shard-local partial vote sums, ONE psum completes them —
# the same collective profile as clause_votes, just a different cache.
register_primitive(Primitive(
    name="indexed_votes",
    xla=indexed.indexed_votes_xla,
    pallas=indexed.indexed_votes,
    partitioning=ClausePartitioning(
        in_specs=(P(None, CLAUSE_AXIS, None),   # positions (m, n, 2o)
                  P(None, None),                # literals (B, 2o)
                  P(CLAUSE_AXIS)),              # polarity (n,)
        out_spec=P(None, None),                 # (B, m) partial votes
        vote_reduce=True,
        clause_padding="zero_polarity",         # sign-0 rows are inert
    ),
))

# Batched event replay: every buffer column replicates (each shard diffs
# its own include slice, so local buffers only name local clauses), the
# index buffers tile over the clause axis exactly like the engine's
# cache_pspec, and no collective is needed. Both routes are the same
# vectorised body — the replay is scatter-bound (see kernels/indexed.py).
register_primitive(Primitive(
    name="index_update",
    xla=indexed.index_update_batched,
    pallas=indexed.index_update_batched,
    partitioning=ClausePartitioning(
        in_specs=(P(None, None, CLAUSE_AXIS),   # lists (m, 2o, cap)
                  P(None, CLAUSE_AXIS),         # counts (m, 2o)
                  P(None, CLAUSE_AXIS, None),   # pos (m, n, 2o)
                  P(None),                      # cls (E,)
                  P(None),                      # clause (E,)
                  P(None),                      # literal (E,)
                  P(None),                      # is_insert (E,)
                  P(None)),                     # valid (E,)
        out_spec=(P(None, None, CLAUSE_AXIS),
                  P(None, CLAUSE_AXIS),
                  P(None, CLAUSE_AXIS, None)),
        vote_reduce=False,
        clause_padding="masked_active",         # invalid events no-op
    ),
))

# Feedback application: clause-elementwise, no collective.
register_primitive(Primitive(
    name="ta_update",
    xla=_ta_update_xla,
    pallas=ta_update_mod.ta_update,
    partitioning=ClausePartitioning(
        in_specs=(P(CLAUSE_AXIS, None),         # ta_row (n, 2o)
                  P(None),                      # lit (2o,)
                  P(CLAUSE_AXIS),               # clause_out
                  P(CLAUSE_AXIS),               # gets_type_i
                  P(CLAUSE_AXIS),               # active
                  P(CLAUSE_AXIS, None)),        # uniforms (n, 2o)
        out_spec=P(CLAUSE_AXIS, None),
        vote_reduce=False,
        clause_padding="masked_active",         # False gate ⇒ zero delta
    ),
))
