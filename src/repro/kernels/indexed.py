"""Falsification-index kernels: matmul-form Eq. 4 + batched event replay.

The paper's clause index scores by iterating *false* literals and walking
their inclusion lists (Eq. 4). The list walk is pointer-chasing — exactly
what an accelerator hates — but the index carries a second, dense view of
the same information: the position matrix ``pos (m, n, 2o)`` is ``NA``
exactly where the clause excludes the literal (``indexing.validate`` pins
``(pos != NA) == include_mask``). The membership mask therefore *is* the
include mask, and Eq. 4 collapses to one contraction:

    falsified(b, i, j)  =  Σ_k false_lit(b, k) · member(i, j, k)  >  0
    votes(b, i)         =  -Σ_j falsified(b, i, j) · pol(j)

No per-sample vmap, no (m, 2o, cap) scatter-max — one MXU/GEMM-friendly
matmul over the literal axis plus a tiny vote reduction. Shard-locality is
free: ``pos`` tiles over the clause axis, partial votes add, and one (B, m)
psum completes the global scores (the ``indexed_votes`` partitioning
contract in ``kernels/backend.py``).

Two bodies live here:

  * :func:`indexed_votes_xla` — the XLA reference (float32 GEMM over 0/1
    operands; counts stay < 2²⁴ so the arithmetic is exact, and the result
    is bit-identical to the integer form).
  * :func:`indexed_votes` — the fused Pallas body: a clause tile's
    membership block meets the batch tile's false-literal block on-chip,
    the falsified bitmask never leaves VMEM, and votes accumulate over the
    clause-tile grid axis (same tiling idiom as ``kernels/clause_eval.py``).

Maintenance is the third body: :func:`index_update_batched` replays a
fixed-shape masked event buffer in O(events) *vectorised* work instead of
``apply_events``'s fully serialised scan-of-cond (one XLA loop iteration
per buffer slot, thousands per train step). Events are netted per TA cell,
grouped per inclusion list by a segment-cumsum over two stable sorts of
the buffer (never over the full state), survivors of deleted entries are
compacted, and net inserts append — a handful of vectorised scatters per
buffer. The result is order-equivalent to sequential replay: identical
``counts`` (exact overflow accounting — every valid event moves its list
count by ±1, cancelling pairs net 0), identical membership (``pos != NA``),
and per-list identical *contents as sets* (intra-list order is the one
thing sequential swap-with-last replay and batched compaction may disagree
on, and nothing observes it: scoring reads membership only, ``validate``
checks the lists↔pos bijection, not slot order). There is no Pallas kernel
body for it — the work is scatter-bound, which Pallas TPU has no edge on —
so both registry routes run the same batched replay (the primitive exists
for routing uniformity and its clause-axis partitioning contract).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Mirrors core.indexing.NA — kernels/ stays free of core/ imports; the
# sentinel is part of the ClauseIndex layout contract (tests pin equality).
NA = jnp.int32(-1)

BATCH_TILE = 8       # sublane-friendly batch tile
CLAUSE_TILE = 128    # clauses per grid step
LANE = 128           # lane width; literal dim padded to a multiple


def _pad_to(x: jax.Array, axis: int, mult: int, value=0) -> jax.Array:
    size = x.shape[axis]
    pad = (-size) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=value)


# ---------------------------------------------------------------------------
# indexed_votes — matmul-form Eq. 4
# ---------------------------------------------------------------------------


def indexed_votes_xla(pos: jax.Array, lit: jax.Array,
                      pol: jax.Array) -> jax.Array:
    """(m, n, 2o) position matrix + (B, 2o) literals + (n,) ±1 polarity →
    (B, m) int32 partial vote sums (Eq. 4: ``-Σ_{j falsified} pol_j``).

    ``pos != NA`` is the membership/include mask, so falsification is one
    contraction of the false-literal indicators against it. The GEMM runs
    in float32 (0/1 operands; per-clause hit counts ≤ 2o < 2²⁴ are exact),
    the vote reduction in int32 — bit-identical to an all-integer einsum,
    and the clause-sharded partial sums add (one psum completes them).
    Padding clause rows are all-``NA`` (never falsified) *and* carry sign-0
    polarity, so they are doubly inert.
    """
    m, n, L = pos.shape
    member = (pos != NA).reshape(m * n, L)                # (m·n, 2o)
    false_lit = (lit == 0)                                # (B, 2o)
    hits = jnp.dot(false_lit.astype(jnp.float32),
                   member.astype(jnp.float32).T)          # (B, m·n)
    falsified = (hits > 0).reshape(-1, m, n)
    return -jnp.einsum("bmn,n->bm", falsified.astype(jnp.int32),
                       pol.astype(jnp.int32))


def _indexed_votes_kernel(pos_ref, lit_ref, pol_ref, o_ref):
    """Grid (B_tiles, m, n_tiles); j = clause-tile index iterates fastest.

    pos_ref: (1, CLAUSE_TILE, L)   int32 — position block (NA = excluded)
    lit_ref: (BATCH_TILE, L)       int32 — literal truth values
    pol_ref: (1, CLAUSE_TILE)      int32 — ±1 clause polarity (0 = padding)
    o_ref:   (BATCH_TILE, 1)       int32 — votes, accumulated over j
    """
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    member = pos_ref[0] != -1                           # (Ct, L); -1 == NA
    false_lit = lit_ref[...] == 0                       # (Bt, L)
    # the falsified bitmask lives entirely on-chip: a clause is falsified
    # iff any of its member literals is false in the sample
    hit = member[None, :, :] & false_lit[:, None, :]    # (Bt, Ct, L)
    falsified = jnp.any(hit, axis=-1)                   # (Bt, Ct)
    sign = pol_ref[0][None, :]                          # (1, Ct)
    votes = jnp.sum(jnp.where(falsified, -sign, 0), axis=1, dtype=jnp.int32)
    o_ref[...] += votes[:, None]


@functools.partial(jax.jit, static_argnames=("interpret",))
def indexed_votes(
    pos: jax.Array,   # (m, n, 2o) int32 position matrix (NA = excluded)
    lit: jax.Array,   # (B, 2o) literal truth values
    pol: jax.Array,   # (n,) int32 ±1 clause polarity
    *,
    interpret: bool = True,
) -> jax.Array:
    """Fused Pallas Eq.-4 falsification votes: (B, m) int32.

    Same contract as :func:`indexed_votes_xla`; ``pol`` is the ±1 sign per
    clause *row of this tensor* — the global polarity single-device, the
    shard's local slice under shard_map (partial sums completed by the one
    vote psum). Padding invariants: clause rows beyond n are padded with
    ``NA`` positions (member-of-nothing → never falsified) and sign 0;
    literal columns beyond 2o are padded ``NA`` in ``pos`` so the literal
    pad value never matters.
    """
    m, n, L = pos.shape
    b = lit.shape[0]

    posp = _pad_to(_pad_to(pos.astype(jnp.int32), 2, LANE, value=-1),
                   1, CLAUSE_TILE, value=-1)
    litp = _pad_to(_pad_to(lit.astype(jnp.int32), 1, LANE, value=1),
                   0, BATCH_TILE, value=1)
    polp = _pad_to(pol.astype(jnp.int32)[None, :], 1, CLAUSE_TILE)
    n_pad, l_pad = posp.shape[1], posp.shape[2]
    b_pad = litp.shape[0]

    grid = (b_pad // BATCH_TILE, m, n_pad // CLAUSE_TILE)
    out = pl.pallas_call(
        _indexed_votes_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, CLAUSE_TILE, l_pad), lambda bb, i, j: (i, j, 0)),
            pl.BlockSpec((BATCH_TILE, l_pad), lambda bb, i, j: (bb, 0)),
            pl.BlockSpec((1, CLAUSE_TILE), lambda bb, i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((BATCH_TILE, 1), lambda bb, i, j: (bb, i)),
        out_shape=jax.ShapeDtypeStruct((b_pad, m), jnp.int32),
        interpret=interpret,
    )(posp, litp, polp)
    return out[:b]


# ---------------------------------------------------------------------------
# index_update — batched event replay (O(events), vectorised)
# ---------------------------------------------------------------------------


def _segment_layout(keys: jax.Array):
    """Stable-sort segment helpers for a (E,) int32 key vector.

    Returns ``(order, sorted_keys, start, last, first_idx)`` where ``order``
    is the stable sort permutation (equal keys keep buffer order), ``start``
    / ``last`` flag segment boundaries in sorted order, and ``first_idx[e]``
    is the sorted position of e's segment head (the cummax trick — no
    segment ids materialised, no data-sized temporaries).
    """
    order = jnp.argsort(keys)                             # stable
    sk = keys[order]
    idx = jnp.arange(keys.shape[0], dtype=jnp.int32)
    start = jnp.concatenate([jnp.ones((1,), bool), sk[1:] != sk[:-1]])
    last = jnp.concatenate([sk[:-1] != sk[1:], jnp.ones((1,), bool)])
    first_idx = jax.lax.associative_scan(
        jnp.maximum, jnp.where(start, idx, 0))
    return order, sk, start, last, first_idx


def index_update_batched(
    lists: jax.Array,      # (m, 2o, cap) int32 clause ids; NA beyond counts
    counts: jax.Array,     # (m, 2o) int32
    pos: jax.Array,        # (m, n, 2o) int32; NA where excluded
    cls: jax.Array,        # (E,) int32 event class
    clause: jax.Array,     # (E,) int32 event clause
    literal: jax.Array,    # (E,) int32 event literal
    is_insert: jax.Array,  # (E,) bool
    valid: jax.Array,      # (E,) bool — fixed-shape buffer mask
    *,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Replay a masked event buffer in one vectorised pass (no scan).

    Precondition (the ``apply_events`` contract): valid events are genuine
    include-boundary crossings in buffer order — an insert lands on a cell
    that is currently excluded, a delete on one currently included (so
    repeated events on one cell strictly alternate). Under it the batched
    result is order-equivalent to sequential replay: identical ``counts``
    (±1 per valid event; insert/delete pairs on a cell cancel exactly, so
    overflow accounting matches to the unit), identical membership
    (``pos != NA``), per-list identical contents as sets with a consistent
    lists↔pos bijection. Only intra-list slot *order* may differ (batched
    compaction preserves relative order and appends net inserts in buffer
    order; sequential swap-with-last may permute) — unobservable to
    scoring, ``validate``, and work accounting. Capacity overflow drops the
    overflowing ids (``mode='drop'``) while counts keep the exact
    sequential value — the config error stays observable via ``validate``.

    ``interpret`` is accepted for kernel-backend routing uniformity and
    ignored: the replay is scatter-bound, so both registry routes run this
    same body (see the module docstring).
    """
    del interpret
    m, L, cap = lists.shape
    n = pos.shape[1]
    E = cls.shape[0]
    idx = jnp.arange(E, dtype=jnp.int32)
    v = valid.astype(bool)
    ins = is_insert.astype(bool)

    # -- net events per TA cell: alternation means an even run is a no-op
    # and an odd run's last event carries the whole run's effect
    cell = (cls * n + clause) * L + literal
    cell_big = jnp.int32(m * n * L)                        # invalid → own tail
    order, _, _, last, first_idx = _segment_layout(
        jnp.where(v, cell, cell_big))
    occ = idx - first_idx                                  # rank within run
    net_sorted = v[order] & last & (occ % 2 == 0)          # odd run length
    effective = jnp.zeros((E,), bool).at[order].set(net_sorted)
    eff_ins = effective & ins
    eff_del = effective & ~ins

    # -- per-list aggregates (dense (m, 2o) temporaries — tiny)
    n_del = jnp.zeros((m, L), jnp.int32).at[
        jnp.where(eff_del, cls, m), literal].add(1, mode="drop")
    n_ins = jnp.zeros((m, L), jnp.int32).at[
        jnp.where(eff_ins, cls, m), literal].add(1, mode="drop")
    new_counts = counts + n_ins - n_del

    # -- membership: net deletes leave the index now; inserts land after
    # their append slots are known
    pos2 = pos.at[jnp.where(eff_del, cls, m), clause, literal].set(
        NA, mode="drop")

    # -- group effective events per inclusion list (c, k): the segment head
    # is the list's representative (rebuilds the row once), and each net
    # insert's rank among its list's inserts fixes its append slot
    glist = cls * L + literal
    glist_big = jnp.int32(m * L)
    order2, _, start2, _, first_idx2 = _segment_layout(
        jnp.where(effective, glist, glist_big))
    rep_sorted = start2 & effective[order2]
    ins_ind = eff_ins[order2].astype(jnp.int32)
    pre = jnp.cumsum(ins_ind) - ins_ind                    # inserts before me
    rank_sorted = pre - pre[first_idx2]                    # …within my list
    rep = jnp.zeros((E,), bool).at[order2].set(rep_sorted)
    ins_rank = jnp.zeros((E,), jnp.int32).at[order2].set(rank_sorted)

    # -- compact survivors of every touched list (one row per representative)
    rows = lists[cls, literal]                             # (E, cap)
    old_cnt = counts[cls, literal]                         # (E,)
    slot = jnp.arange(cap, dtype=jnp.int32)[None, :]
    safe_ids = jnp.where(rows >= 0, rows, 0)
    still = pos2[cls[:, None], safe_ids, literal[:, None]] != NA
    surv = (slot < old_cnt[:, None]) & (rows >= 0) & still # (E, cap)
    new_slot = jnp.cumsum(surv.astype(jnp.int32), axis=1) - 1
    new_rows = jnp.full((E, cap), NA, jnp.int32).at[
        idx[:, None], jnp.where(surv, new_slot, cap)].set(
        jnp.where(surv, rows, NA), mode="drop")

    # -- scatter everything back: representative rows, survivor positions,
    # then net-insert appends (scatters touch disjoint cells by netting)
    rep_c = jnp.where(rep, cls, m)                         # OOB → drop
    new_lists = lists.at[rep_c, literal].set(new_rows, mode="drop")
    wc = jnp.where(surv & rep[:, None], cls[:, None], m)
    pos3 = pos2.at[
        wc, safe_ids, jnp.broadcast_to(literal[:, None], (E, cap))].set(
        new_slot, mode="drop")

    base = old_cnt - n_del[cls, literal]                   # survivors per list
    app_slot = base + ins_rank
    ins_c = jnp.where(eff_ins, cls, m)
    new_lists = new_lists.at[ins_c, literal, app_slot].set(
        clause, mode="drop")
    pos3 = pos3.at[ins_c, clause, literal].set(app_slot, mode="drop")
    return new_lists, new_counts, pos3
