"""Pallas TPU kernels: bit-packed clause evaluation (+ fused voting).

The paper's dense hot spot is evaluating m·n conjunctive clauses over 2o
literals. TPU-native layout (DESIGN.md §2):

  * literals bit-packed 32/uint32 word → operand bytes drop 32×;
  * clauses on sublanes (tiles of CLAUSE_TILE), packed words on lanes
    (padded to a multiple of 128 — MXU/VPU lane width);
  * falsification is `any(include & ~literals)` — one VPU pass, no MXU;
  * the vote reduction is fused so (B, m, n) clause outputs never
    round-trip through HBM: the kernel emits (B, m) votes directly.

VMEM budget per grid step (defaults): include block CLAUSE_TILE×W_pad×4B
+ literal block BATCH_TILE×W_pad×4B; W_pad ≤ 1280 (IMDb-40k literals) →
≈ 0.7 MB, comfortably inside ~16 MB VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BATCH_TILE = 8       # sublane-friendly batch tile
CLAUSE_TILE = 128    # clauses per grid step
LANE = 128           # lane width; packed-word dim padded to a multiple


def _pad_to(x: jax.Array, axis: int, mult: int, value=0) -> jax.Array:
    size = x.shape[axis]
    pad = (-size) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=value)


# ---------------------------------------------------------------------------
# Fused eval + vote kernel
# ---------------------------------------------------------------------------


def _votes_kernel(inc_ref, lit_ref, pol_ref, o_ref):
    """Grid (B_tiles, m, n_tiles); j = clause-tile index iterates fastest.

    inc_ref: (1, CLAUSE_TILE, W)   uint32 — include masks of clause tile
    lit_ref: (BATCH_TILE, W)       uint32 — packed literals
    pol_ref: (1, CLAUSE_TILE)      int32  — ±1 clause polarity (0 = padding)
    o_ref:   (BATCH_TILE, 1)       int32  — votes, accumulated over j
    """
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    inc = inc_ref[0]                                    # (Ct, W)
    lit = lit_ref[...]                                  # (Bt, W)
    # violation: included literal that is false
    viol = inc[None, :, :] & (~lit)[:, None, :]         # (Bt, Ct, W)
    falsified = jnp.any(viol != 0, axis=-1)             # (Bt, Ct)
    # polarity arrives as data (not recomputed from the global clause id),
    # so a clause shard passes its local ±1 slice and the kernel is
    # placement-agnostic; clause padding carries sign 0
    sign = pol_ref[0][None, :]                          # (1, Ct)
    votes = jnp.sum(jnp.where(falsified, 0, sign), axis=1, dtype=jnp.int32)
    o_ref[...] += votes[:, None]


@functools.partial(jax.jit, static_argnames=("interpret",))
def clause_votes_packed(
    include_packed: jax.Array,  # (m, n, W) uint32
    lit_packed: jax.Array,      # (B, W) uint32
    pol: jax.Array,             # (n,) int32 ±1 clause polarity
    *,
    interpret: bool = True,
) -> jax.Array:
    """Fused bit-packed clause evaluation + polarity vote: (B, m) int32.

    ``pol`` is the ±1 vote sign per clause *row of this tensor* — the global
    polarity single-device, the shard's local slice under shard_map (where
    the returned votes are partial sums completed by one psum over the
    clause axis — the registry's ``clause_votes`` partitioning contract).

    Padding invariants: include words beyond 2o are 0 (never falsify);
    literal words beyond 2o may be anything (ANDed against 0 includes);
    clause rows beyond n get sign 0.
    """
    m, n, w = include_packed.shape
    b = lit_packed.shape[0]

    inc = _pad_to(_pad_to(include_packed, 2, LANE), 1, CLAUSE_TILE)
    lit = _pad_to(_pad_to(lit_packed, 1, LANE), 0, BATCH_TILE)
    polp = _pad_to(pol.astype(jnp.int32)[None, :], 1, CLAUSE_TILE)
    n_pad, w_pad = inc.shape[1], inc.shape[2]
    b_pad = lit.shape[0]

    grid = (b_pad // BATCH_TILE, m, n_pad // CLAUSE_TILE)
    out = pl.pallas_call(
        _votes_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, CLAUSE_TILE, w_pad), lambda bb, i, j: (i, j, 0)),
            pl.BlockSpec((BATCH_TILE, w_pad), lambda bb, i, j: (bb, 0)),
            pl.BlockSpec((1, CLAUSE_TILE), lambda bb, i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((BATCH_TILE, 1), lambda bb, i, j: (bb, i)),
        out_shape=jax.ShapeDtypeStruct((b_pad, m), jnp.int32),
        interpret=interpret,
    )(inc, lit, polp)
    return out[:b]


# ---------------------------------------------------------------------------
# Raw clause-output kernel (training needs per-clause outputs)
# ---------------------------------------------------------------------------


def _outputs_kernel(inc_ref, lit_ref, o_ref):
    """Grid (B_tiles, m, n_tiles): emit clause outputs for one tile."""
    inc = inc_ref[0]                                    # (Ct, W)
    lit = lit_ref[...]                                  # (Bt, W)
    viol = inc[None, :, :] & (~lit)[:, None, :]
    falsified = jnp.any(viol != 0, axis=-1)             # (Bt, Ct)
    o_ref[...] = jnp.where(falsified, 0, 1).astype(jnp.int8)[:, None, :]


@functools.partial(jax.jit, static_argnames=("interpret",))
def clause_outputs_packed(
    include_packed: jax.Array,  # (m, n, W) uint32
    lit_packed: jax.Array,      # (B, W) uint32
    *,
    interpret: bool = True,
) -> jax.Array:
    """Bit-packed clause outputs: (B, m, n) int8 (empty clauses → 1)."""
    m, n, w = include_packed.shape
    b = lit_packed.shape[0]

    inc = _pad_to(_pad_to(include_packed, 2, LANE), 1, CLAUSE_TILE)
    lit = _pad_to(_pad_to(lit_packed, 1, LANE), 0, BATCH_TILE)
    n_pad, w_pad = inc.shape[1], inc.shape[2]
    b_pad = lit.shape[0]

    grid = (b_pad // BATCH_TILE, m, n_pad // CLAUSE_TILE)
    out = pl.pallas_call(
        _outputs_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, CLAUSE_TILE, w_pad), lambda bb, i, j: (i, j, 0)),
            pl.BlockSpec((BATCH_TILE, w_pad), lambda bb, i, j: (bb, 0)),
        ],
        out_specs=pl.BlockSpec(
            (BATCH_TILE, 1, CLAUSE_TILE), lambda bb, i, j: (bb, i, j)
        ),
        out_shape=jax.ShapeDtypeStruct((b_pad, m, n_pad), jnp.int8),
        interpret=interpret,
    )(inc, lit)
    return out[:b, :, :n]
