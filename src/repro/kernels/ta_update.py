"""Pallas TPU kernel: Type I / Type II TA feedback application.

Second hot spot of TM *learning*: given precomputed clause outputs and the
per-clause feedback routing (active gate, Type I vs Type II), apply the
per-(clause, literal) state transitions. Elementwise over (n, 2o) with two
broadcast operands — a pure VPU kernel; tiling keeps the uniforms and TA
block resident in VMEM so the update is one HBM read + one write of the
TA state per step.

Layout: clauses on sublanes (CLAUSE_TILE), literals on lanes (LIT_TILE,
multiple of 128).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

CLAUSE_TILE = 8
LIT_TILE = 128


def _pad_to(x, axis, mult, value=0):
    size = x.shape[axis]
    pad = (-size) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=value)


def _update_kernel(
    ta_ref,        # (Ct, Lt) int16
    lit_ref,       # (1, Lt) int8   — literal truth values
    cout_ref,      # (Ct, 1) int8   — clause outputs (learning semantics)
    type_i_ref,    # (Ct, 1) int8   — 1: Type I, 0: Type II (inactive → gate)
    active_ref,    # (Ct, 1) int8   — clause update gate (bernoulli(p))
    u_ref,         # (Ct, Lt) float32 — uniforms for Type I branches
    o_ref,         # (Ct, Lt) int16
    *,
    n_states: int,
    s: float,
    boost_true_positive: bool,
):
    ta = ta_ref[...]
    lit = lit_ref[0][None, :]                     # (1, Lt)
    c1 = cout_ref[...] == 1                       # (Ct, 1)
    is_t1 = type_i_ref[...] == 1
    active = active_ref[...] == 1
    u = u_ref[...]
    include = ta > n_states

    inv_s = 1.0 / s
    p_reward = 1.0 if boost_true_positive else 1.0 - inv_s
    l1 = lit == 1

    # Type I deltas
    reward = c1 & l1 & (u < p_reward)
    penalty = ((c1 & ~l1) | ~c1) & (u < inv_s)
    d1 = reward.astype(jnp.int16) - penalty.astype(jnp.int16)
    # Type II deltas
    d2 = (c1 & ~l1 & ~include).astype(jnp.int16)

    delta = jnp.where(active & is_t1, d1, jnp.where(active & ~is_t1, d2, 0))
    o_ref[...] = jnp.clip(ta + delta, 1, 2 * n_states).astype(jnp.int16)


@functools.partial(
    jax.jit, static_argnames=("n_states", "s", "boost_true_positive", "interpret")
)
def ta_update(
    ta_row: jax.Array,       # (n, 2o) int16 — one class's TA states
    lit: jax.Array,          # (2o,) int8/uint8
    clause_out: jax.Array,   # (n,) int8
    gets_type_i: jax.Array,  # (n,) bool/int8
    active: jax.Array,       # (n,) bool/int8
    uniforms: jax.Array,     # (n, 2o) float32
    *,
    n_states: int,
    s: float,
    boost_true_positive: bool = False,
    interpret: bool = True,
) -> jax.Array:
    """Apply one class-round of feedback. Returns updated (n, 2o) int16."""
    n, L = ta_row.shape
    ta = _pad_to(_pad_to(ta_row, 1, LIT_TILE), 0, CLAUSE_TILE)
    n_pad, l_pad = ta.shape
    litp = _pad_to(lit.astype(jnp.int8)[None, :], 1, LIT_TILE)
    cout = _pad_to(clause_out.astype(jnp.int8)[:, None], 0, CLAUSE_TILE)
    t1 = _pad_to(gets_type_i.astype(jnp.int8)[:, None], 0, CLAUSE_TILE)
    act = _pad_to(active.astype(jnp.int8)[:, None], 0, CLAUSE_TILE)
    # uniform padding value 1.0 ⇒ no spurious transitions in padded region
    u = _pad_to(_pad_to(uniforms, 1, LIT_TILE, 1.0), 0, CLAUSE_TILE, 1.0)

    grid = (n_pad // CLAUSE_TILE, l_pad // LIT_TILE)
    out = pl.pallas_call(
        functools.partial(
            _update_kernel,
            n_states=n_states,
            s=s,
            boost_true_positive=boost_true_positive,
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((CLAUSE_TILE, LIT_TILE), lambda i, j: (i, j)),
            pl.BlockSpec((1, LIT_TILE), lambda i, j: (0, j)),
            pl.BlockSpec((CLAUSE_TILE, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((CLAUSE_TILE, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((CLAUSE_TILE, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((CLAUSE_TILE, LIT_TILE), lambda i, j: (i, j)),
        ],
        out_specs=pl.BlockSpec((CLAUSE_TILE, LIT_TILE), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n_pad, l_pad), jnp.int16),
        interpret=interpret,
    )(ta, litp, cout, t1, act, u)
    return out[:n, :L]
