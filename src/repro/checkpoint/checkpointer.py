"""Sharded, async, atomic checkpointing with resharding restore.

Production contract (DESIGN.md §4):
  * each process writes only its addressable shards (here: one process owns
    everything, but the layout is per-shard files keyed by global offsets);
  * writes go to ``step_XXXXXX.tmp/`` and are atomically renamed after the
    manifest is fsync'd — a crash mid-write can never corrupt the latest
    checkpoint (restart picks the newest *committed* step);
  * async: the device→host copy happens at save() call time (cheap), the
    serialization runs on a worker thread so the train loop continues;
  * restore() takes the *target* sharding — elastic restarts may use a
    different mesh; arrays are re-laid-out on load (reshard-on-restore);
  * retention: keep the newest ``keep`` checkpoints, always keep multiples
    of ``keep_every`` (archival).
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
from pathlib import Path

import jax
import numpy as np

_FLAT_SEP = "//"


def _flatten(tree):
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for kp, leaf in flat:
        key = _FLAT_SEP.join(
            str(getattr(k, "key", getattr(k, "idx", k))) for k in kp)
        out[key] = leaf
    return out


class Checkpointer:
    def __init__(self, directory, *, keep: int = 3, keep_every: int = 0):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self.keep_every = keep_every
        self._thread: threading.Thread | None = None
        self._error: BaseException | None = None

    # -- save ---------------------------------------------------------------

    def save(self, step: int, tree, *, blocking: bool = False):
        """Snapshot to host, then serialize on a worker thread."""
        self.wait()  # one in-flight save at a time
        host = {k: np.asarray(v) for k, v in _flatten(tree).items()}
        treedef = jax.tree_util.tree_structure(tree)

        def work():
            try:
                self._write(step, host, str(treedef))
                self._gc()
            except BaseException as e:  # noqa: BLE001
                self._error = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()
        if blocking:
            self.wait()

    def _write(self, step, host, treedef_str):
        tmp = self.dir / f"step_{step:08d}.tmp"
        final = self.dir / f"step_{step:08d}"
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir()
        manifest = {"step": step, "time": time.time(), "arrays": {},
                    "treedef": treedef_str}
        for key, arr in host.items():
            fn = f"{abs(hash(key)) % 10**12:012d}.npy"
            np.save(tmp / fn, arr)
            manifest["arrays"][key] = {
                "file": fn, "shape": list(arr.shape), "dtype": str(arr.dtype)}
        with open(tmp / "manifest.json", "w") as f:
            json.dump(manifest, f)
            f.flush()
            os.fsync(f.fileno())
        if final.exists():
            shutil.rmtree(final)
        tmp.rename(final)  # atomic commit

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            e, self._error = self._error, None
            raise e

    # -- restore --------------------------------------------------------------

    def latest_step(self) -> int | None:
        steps = sorted(
            int(p.name.split("_")[1]) for p in self.dir.glob("step_*")
            if not p.name.endswith(".tmp")
            and (p / "manifest.json").exists())
        return steps[-1] if steps else None

    def restore(self, step: int, target_tree, shardings=None):
        """Load into the structure of ``target_tree``; if ``shardings`` is
        given (pytree of jax.sharding.Sharding), device_put per leaf —
        this is the elastic reshard-on-restore path."""
        path = self.dir / f"step_{step:08d}"
        manifest = json.loads((path / "manifest.json").read_text())
        flat_target = _flatten(target_tree)
        flat_shard = _flatten(shardings) if shardings is not None else {}
        loaded = {}
        for key, leaf in flat_target.items():
            meta = manifest["arrays"].get(key)
            if meta is None:
                raise KeyError(f"checkpoint missing array {key!r}")
            arr = np.load(path / meta["file"])
            if tuple(arr.shape) != tuple(leaf.shape):
                raise ValueError(
                    f"{key}: checkpoint shape {arr.shape} != {leaf.shape}")
            sh = flat_shard.get(key)
            loaded[key] = (jax.device_put(arr, sh) if sh is not None
                           else jax.numpy.asarray(arr))
        # unflatten by matching the flat order of the target
        leaves_order = list(_flatten(target_tree))
        treedef = jax.tree_util.tree_structure(target_tree)
        return jax.tree_util.tree_unflatten(
            treedef, [loaded[k] for k in leaves_order])

    # -- retention ------------------------------------------------------------

    def _gc(self):
        steps = sorted(
            int(p.name.split("_")[1]) for p in self.dir.glob("step_*")
            if not p.name.endswith(".tmp"))
        doomed = steps[:-self.keep] if self.keep else []
        for s in doomed:
            if self.keep_every and s % self.keep_every == 0:
                continue
            shutil.rmtree(self.dir / f"step_{s:08d}", ignore_errors=True)
