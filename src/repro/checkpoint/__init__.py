"""checkpoint substrate."""
