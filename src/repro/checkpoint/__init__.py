"""checkpoint substrate: generic sharded Checkpointer + the versioned TM
checkpoint schema (state + config fingerprint only — tm_store.py)."""
from repro.checkpoint.checkpointer import Checkpointer
from repro.checkpoint.tm_store import (
    SCHEMA_VERSION,
    CheckpointMismatch,
    checkpoint_tree,
    config_fingerprint,
    load_tm,
    save_tm,
    validate_meta,
)

__all__ = [
    "Checkpointer", "SCHEMA_VERSION", "CheckpointMismatch",
    "checkpoint_tree", "config_fingerprint", "load_tm", "save_tm",
    "validate_meta",
]
