"""Versioned, engine-agnostic TM checkpoints (schema v1) over ``Checkpointer``.

Replaces the legacy driver pytree schema (``as_pytree``/``load_pytree``),
which persisted the falsification index alongside the TA state. Schema v1
persists **state + config fingerprint only**:

  * every engine cache — including the paper's clause index — is derived
    data; persisting one would pin the topology it was built on (shard-local
    cache layouts change shape with the clause-shard count). Restore rebuilds
    caches on the *restoring* topology via ``TMSession.prepare`` — the same
    reshard-on-restore machinery the fault-tolerant trainer uses — so a
    checkpoint written under ``Topology(clause_shards=4)`` loads bit-exactly
    under any other placement. The async stale-vote accumulator
    (``TMBundle.vote_acc``, DESIGN.md §11) is the same kind of rebuildable
    state: it is never persisted — restore under ``async_votes=K`` seeds a
    fresh zero ``VoteAccumulator`` on the restoring topology, and the
    cold-start staleness transient decays within one refresh window;
  * the config fingerprint (sha256 over the canonical ``TMConfig`` field
    dump) catches restoring into a machine whose semantics differ — shapes
    alone cannot (e.g. a changed ``s`` or ``threshold`` keeps every shape).

On disk this is a normal ``Checkpointer`` step directory (atomic commit,
retention, async save), holding ``schema_version``, ``fingerprint``,
``step`` and ``ta_state`` arrays. The fingerprint is validated *before* the
state is read, so a config mismatch fails with a clear error rather than a
shape complaint.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
from pathlib import Path

import numpy as np

from repro.checkpoint.checkpointer import Checkpointer

SCHEMA_VERSION = 1
_DIGEST_BYTES = 32  # sha256


class CheckpointMismatch(ValueError):
    """Checkpoint incompatible with the restoring machine's config/schema."""


# Execution details that do not change what a checkpoint *is*: a state
# trained on one kernel backend restores onto any other (results are
# bit-exact across backends by the registry contract), exactly like
# restoring onto a different topology.
_EXECUTION_FIELDS = frozenset({"backend"})


def config_fingerprint(cfg) -> np.ndarray:
    """(32,) uint8 sha256 over the canonical config field dump.

    Every *model* dataclass field participates (capacities included: they
    size the rebuilt caches); pure execution fields (``_EXECUTION_FIELDS``)
    do not. Values render via ``repr`` for a stable text form that also
    covers non-JSON leaves like dtypes.
    """
    fields = {f.name: repr(getattr(cfg, f.name))
              for f in dataclasses.fields(cfg)
              if f.name not in _EXECUTION_FIELDS}
    blob = json.dumps(fields, sort_keys=True).encode()
    return np.frombuffer(hashlib.sha256(blob).digest(), np.uint8).copy()


def checkpoint_tree(cfg, ta_state, *, step: int = 0) -> dict:
    """The schema-v1 payload for one TM state (a flat dict pytree)."""
    return {
        "schema_version": np.asarray(SCHEMA_VERSION, np.int32),
        "fingerprint": config_fingerprint(cfg),
        "step": np.asarray(step, np.int32),
        "ta_state": ta_state,
    }


def validate_meta(loaded: dict, cfg, *, where: str = "checkpoint") -> None:
    """Raise ``CheckpointMismatch`` on a schema or fingerprint mismatch."""
    version = int(np.asarray(loaded["schema_version"]))
    if version != SCHEMA_VERSION:
        raise CheckpointMismatch(
            f"{where}: schema version {version} != supported "
            f"{SCHEMA_VERSION}")
    want = config_fingerprint(cfg)
    got = np.asarray(loaded["fingerprint"], np.uint8)
    if got.shape != want.shape or not np.array_equal(got, want):
        raise CheckpointMismatch(
            f"{where}: config fingerprint mismatch — the checkpoint was "
            f"written with a different TMConfig than the restoring "
            f"machine's (saved {bytes(got[:8]).hex()}…, restoring "
            f"{bytes(want[:8]).hex()}…); load with the original config")


# One Checkpointer per directory: its save() serialises in-flight writes
# (one at a time) and surfaces a failed async write on the *next* call — a
# throwaway instance per save would silently swallow non-blocking errors
# and race concurrent writer threads over the same directory.
_CHECKPOINTERS: dict[str, Checkpointer] = {}


def _checkpointer(directory, keep: int | None = None) -> Checkpointer:
    key = str(Path(directory).resolve())
    ck = _CHECKPOINTERS.get(key)
    if ck is None:
        ck = Checkpointer(directory, keep=3 if keep is None else keep)
        _CHECKPOINTERS[key] = ck
    elif keep is not None:
        ck.keep = keep
    return ck


def save_tm(directory, cfg, ta_state, *, step: int = 0, keep: int = 3,
            blocking: bool = True) -> None:
    """Write one schema-v1 checkpoint step (atomic, retained per ``keep``)."""
    _checkpointer(directory, keep=keep).save(
        step, checkpoint_tree(cfg, ta_state, step=step), blocking=blocking)


def load_tm(directory, cfg, like_ta_state, *, step: int | None = None,
            sharding=None):
    """Restore ``(ta_state, step)`` from the newest (or given) step.

    ``like_ta_state`` supplies the target shape/dtype (any array or
    ShapeDtypeStruct-alike with ``.shape``); ``sharding`` (optional
    ``jax.sharding.Sharding``) lands the state directly on the restoring
    topology's placement — reshard-on-restore. Meta is validated *first* so
    config mismatches surface as ``CheckpointMismatch``, never as a shape
    error from the state read.
    """
    ckpt = _checkpointer(directory)
    ckpt.wait()  # drain any in-flight save (and surface its error) first
    if step is None:
        step = ckpt.latest_step()
        if step is None:
            raise FileNotFoundError(
                f"no committed TM checkpoint steps under {directory}")
    try:
        meta = ckpt.restore(step, {
            "schema_version": np.asarray(0, np.int32),
            "fingerprint": np.zeros(_DIGEST_BYTES, np.uint8)})
    except KeyError as e:  # pre-v1 layouts carry no schema/fingerprint
        raise CheckpointMismatch(
            f"{directory} step {step}: not a schema-v1 TM checkpoint "
            f"(missing {e}); pre-versioning checkpoints (the legacy driver "
            "pytree) are not loadable — re-save from the source state"
        ) from None
    validate_meta(meta, cfg, where=f"{directory} step {step}")
    shardings = ({"ta_state": sharding} if sharding is not None else None)
    loaded = ckpt.restore(step, {"ta_state": like_ta_state}, shardings)
    return loaded["ta_state"], step


__all__ = [
    "SCHEMA_VERSION", "CheckpointMismatch", "checkpoint_tree",
    "config_fingerprint", "load_tm", "save_tm", "validate_meta",
]
