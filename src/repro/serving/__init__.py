"""Continuous-batching async TM serving runtime (DESIGN.md §10).

The serving analogue of what ``Topology``/``TMSession`` did for training:
the compute side was already placement-transparent, this package adds the
host side a production server needs on top of it —

  * ``aot``      — AOT bucket cache: every padding bucket's scores graph
                   ``jit(...).lower(...).compile()``-d at startup, keyed on
                   ``(engine, bucket, session fingerprint)``, so the hot
                   loop can never compile;
  * ``runtime``  — ``AsyncTMServer``: a dispatch thread forms batches from
                   a bounded backlog (typed ``Overloaded`` rejection past
                   the row/byte budget) while a result thread blocks on
                   device futures and completes per-request promises —
                   host batching of batch N+1 overlaps device compute of
                   batch N;
  * ``fairness`` — per-tenant weighted round-robin admission with
                   per-tenant latency accounting;
  * ``loadgen``  — open-loop (Poisson arrival) load generation and the
                   ``sustained_load`` record: offered-vs-achieved curve,
                   rejection rate, knee point.

``launch/tm_serve.py`` is the CLI over this package and keeps the old
synchronous drain loop only as the measured baseline.
"""
from repro.serving.aot import (
    AOTBucketCache, AOTCacheMiss, bucket_for, buckets)
from repro.serving.fairness import TenantQueues, TenantStats
from repro.serving.loadgen import (
    find_knee, holds, poisson_arrivals, run_step, sustained_load)
from repro.serving.runtime import (
    AsyncTMServer, Backlog, Overloaded, Promise, ScoreResult, SyncTMServer)

__all__ = [
    "AOTBucketCache", "AOTCacheMiss", "AsyncTMServer", "Backlog",
    "Overloaded", "Promise", "ScoreResult", "SyncTMServer", "TenantQueues",
    "TenantStats", "bucket_for", "buckets", "find_knee", "holds",
    "poisson_arrivals", "run_step", "sustained_load",
]
