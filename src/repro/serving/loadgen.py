"""Open-loop load generation + the ``sustained_load`` benchmark record.

The old serving benchmark drained a fixed 96-request backlog — a *closed
loop*, where the generator implicitly waits for the server, so the
measured "throughput" is just capacity and the percentiles hide every
queueing effect. Production load is **open-loop**: arrivals are a Poisson
process that does not care whether previous requests completed. This
module submits on that schedule (sleeping to each arrival time, bursting
every due request), sweeps a ladder of offered rates, and reports the
curve a capacity planner actually needs:

  * offered vs achieved throughput per step,
  * completion p50/p95/p99 per step,
  * rejection rate (typed ``Overloaded`` admissions) per step,
  * the **knee**: the highest offered rate the server still holds
    (achieved ≥ 90% of offered with ≤ 1% rejections) — past it the curve
    flattens into rejections, not latency collapse, because admission
    control bounds the backlog.

The record lands in ``BENCH_tm_serve.json`` (schema 2,
docs/BENCH_SCHEMAS.md) next to the synchronous loop's saturation
throughput on the same load, so the async-runtime gain is one comparison.
"""
from __future__ import annotations

import time

import numpy as np

from repro.serving.runtime import AsyncTMServer, ScoreResult


def poisson_arrivals(rps: float, duration_s: float,
                     rng: np.random.Generator) -> np.ndarray:
    """Arrival offsets (seconds, ascending) of a Poisson process at
    ``rps`` over ``duration_s`` — at least one arrival."""
    n = max(1, int(round(rps * duration_s)))
    gaps = rng.exponential(1.0 / rps, n)
    arrivals = np.cumsum(gaps)
    return arrivals[arrivals <= duration_s] if arrivals.size > 1 else arrivals


def run_step(server: AsyncTMServer, xs: np.ndarray, *, rps: float,
             duration_s: float, rng: np.random.Generator,
             tenant_of=None, wait_timeout: float = 60.0) -> dict:
    """Offer one open-loop Poisson step to a running server.

    Submissions happen on the arrival schedule regardless of completions
    (the open-loop property); after the last arrival the step drains and
    summarises. ``xs`` is a pool of request rows cycled per arrival;
    ``tenant_of(i)`` names the tenant of arrival ``i`` (default: one
    tenant).
    """
    arrivals = poisson_arrivals(rps, duration_s, rng)
    n = arrivals.size
    before = server.stats()
    promises = []
    t0 = time.perf_counter()
    i = 0
    while i < n:
        now = time.perf_counter() - t0
        if arrivals[i] > now:
            time.sleep(min(arrivals[i] - now, 0.005))
            continue
        while i < n and arrivals[i] <= now:  # burst every due arrival
            tenant = tenant_of(i) if tenant_of is not None else "default"
            promises.append(server.submit(xs[i % len(xs)], tenant=tenant))
            i += 1
    server.drain(timeout=wait_timeout)
    results = [p.wait(wait_timeout) for p in promises]
    after = server.stats()

    done = [r for r in results if isinstance(r, ScoreResult)]
    rejected = len(results) - len(done)
    lat_ms = np.asarray([r.latency_s for r in done]) * 1e3 if done else None
    last_done = max((r.done_s for r in done), default=t0)
    elapsed = max(last_done - t0, 1e-9)
    batches = after["batches"] - before["batches"]
    rows_padded = after["rows_padded"] - before["rows_padded"]
    step = {
        "offered_rps": round(n / max(float(arrivals[-1]), 1e-9), 1),
        "achieved_rps": round(len(done) / elapsed, 1),
        "requests": n,
        "completed": len(done),
        "rejected": rejected,
        "rejection_rate": round(rejected / n, 4),
        "batches": batches,
        "mean_batch": round(len(done) / batches, 2) if batches else 0.0,
        "padding_efficiency": round(
            (after["rows_real"] - before["rows_real"]) / rows_padded, 4)
        if rows_padded else 1.0,
    }
    if lat_ms is not None:
        p50, p95, p99 = np.percentile(lat_ms, [50, 95, 99])
        step["latency_ms"] = {"p50": round(float(p50), 3),
                              "p95": round(float(p95), 3),
                              "p99": round(float(p99), 3),
                              "mean": round(float(lat_ms.mean()), 3)}
    return step


def holds(step: dict) -> bool:
    """Did the server sustain this step's offered load?

    Primary signal: rejections ≤ 1% — with a bounded backlog, a rate past
    capacity fills the budget and turns into typed rejections within a
    step. Secondary guard: achieved ≥ 0.8 × offered, which catches a
    just-past-capacity step whose backlog did not fill before the step
    ended. The factor is 0.8 (not ~1.0) because ``achieved_rps`` divides
    by an elapsed that includes the final batch's drain tail, biasing the
    ratio low on short steps even when the server kept up perfectly.
    """
    return (step["rejection_rate"] <= 0.01
            and step["achieved_rps"] >= 0.8 * step["offered_rps"])


def find_knee(steps: list[dict]) -> dict:
    """The knee of an offered-vs-achieved curve (steps in offered order).

    The knee is the last step that ``holds``; when nothing holds (every
    step already past capacity) it falls back to the max-achieved step,
    named in ``criterion``.
    """
    holding = [i for i, s in enumerate(steps) if holds(s)]
    if holding:
        i = holding[-1]
        criterion = "last step with achieved >= 0.8*offered and <=1% rejected"
    else:
        i = int(np.argmax([s["achieved_rps"] for s in steps]))
        criterion = "no step held offered load; max achieved"
    return {"index": i, "offered_rps": steps[i]["offered_rps"],
            "achieved_rps": steps[i]["achieved_rps"],
            "criterion": criterion}


def sustained_load(server: AsyncTMServer, xs: np.ndarray, *,
                   rps_steps, step_duration_s: float = 0.5,
                   seed: int = 0, tenant_of=None) -> dict:
    """Sweep an offered-rate ladder against a server; the schema-2
    ``sustained_load`` record (sans the sync baseline the caller adds).

    Starts the server if needed, runs every step open-loop back to back,
    and asserts the AOT hot-loop invariant: the cache compiled nothing
    after startup (``lowerings`` constant, ``misses`` zero).
    """
    rng = np.random.default_rng(seed)
    server.start()
    lowerings_before = server.aot.counters()["lowerings"]
    steps = [run_step(server, xs, rps=float(rps),
                      duration_s=step_duration_s, rng=rng,
                      tenant_of=tenant_of)
             for rps in rps_steps]
    aot = server.aot.counters()
    hot_loop_compiles = aot["lowerings"] - lowerings_before
    assert hot_loop_compiles == 0 and aot["misses"] == 0, (
        f"AOT invariant violated: {hot_loop_compiles} lowerings and "
        f"{aot['misses']} misses inside the timed loop")
    stats = server.stats()
    return {
        "open_loop": True,
        "engine": server.engine,
        "step_duration_s": step_duration_s,
        "steps": steps,
        "knee": find_knee(steps),
        "tenants": stats["tenants"],
        "aot": {**aot, "hot_loop_compiles": hot_loop_compiles},
    }
