"""Continuous-batching async TM server: dispatch/result threads over an
AOT bucket cache, bounded-backlog admission, per-tenant fairness.

The synchronous loop this replaces (kept in ``launch/tm_serve.py`` as the
measured baseline) serialises every phase: drain queue → pad → dispatch →
*block on device* → repeat. Here the phases pipeline:

  * ``submit`` (any thread) — admission control first: past the backlog's
    row/byte budget the request resolves *immediately* with a typed
    ``Overloaded`` result (callers shed load instead of queueing into a
    latency cliff); admitted requests enter their tenant's FIFO.
  * the **dispatch thread** — takes up to a top-bucket's worth of rows by
    weighted round-robin (``fairness.TenantQueues``), pads to the bucket,
    and dispatches through the AOT cache. Dispatch is asynchronous — the
    thread does not wait for the device — so batch N+1 is padded and
    queued on the device stream while batch N computes. An ``inflight``
    slot semaphore applies backpressure: the dispatch thread (never
    ``submit``) blocks for a free slot *before forming* a batch, and a
    slot frees only when a batch fully completes.
  * the **result thread** — blocks on each in-flight batch's device
    arrays in dispatch order, completes the per-request promises with
    ``ScoreResult``, records per-tenant latency, releases the backlog
    budget, then frees the batch's in-flight slot.

There is no batching timer: the in-flight device compute *is* the batching
window. Because formation waits for a slot and slots free at completion,
exactly one batch forms per completed compute window and carries that
window's arrivals — small at low load, full at saturation (continuous
batching). Gating *formation* rather than dispatch is what keeps batches
from fragmenting at mid load: an ungated dispatch thread would race ahead,
draining the queue into several tiny padded batches per window and burning
capacity on padding. Every piece of the engine is also callable synchronously
(``step()``) so admission, fairness, and completion are unit-testable with
a deterministic clock and no threads (tests/test_tm_serving.py).
"""
from __future__ import annotations

import dataclasses
import queue
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.serving.aot import AOTBucketCache, bucket_for, buckets
from repro.serving.fairness import TenantQueues, TenantStats


@dataclasses.dataclass(frozen=True)
class ScoreResult:
    """Successful completion: one request's class scores + timing."""

    scores: np.ndarray  # (n_classes,)
    tenant: str
    arrival_s: float
    done_s: float

    @property
    def latency_s(self) -> float:
        """Arrival→completion latency (queueing + padding + compute)."""
        return self.done_s - self.arrival_s


@dataclasses.dataclass(frozen=True)
class Overloaded:
    """Typed admission rejection: the backlog budget was exhausted.

    Resolved onto the promise *synchronously inside* ``submit`` — an
    overloaded server sheds load in O(1) without touching the queues, so
    rejection cost does not scale with the backlog it protects.
    """

    tenant: str
    arrival_s: float
    backlog_rows: int
    backlog_bytes: int
    max_rows: int
    max_bytes: int


class Promise:
    """Single-assignment completion slot for one submitted request."""

    __slots__ = ("_event", "result")

    def __init__(self):
        self._event = threading.Event()
        self.result = None

    def resolve(self, result) -> None:
        """Deliver the ``ScoreResult`` / ``Overloaded`` (exactly once)."""
        self.result = result
        self._event.set()

    @property
    def done(self) -> bool:
        """True once ``resolve`` ran."""
        return self._event.is_set()

    def wait(self, timeout: float | None = None):
        """Block until resolved; returns the result or raises
        ``TimeoutError``."""
        if not self._event.wait(timeout):
            raise TimeoutError("request not completed within timeout")
        return self.result


class Backlog:
    """Bounded row/byte admission budget over queued + in-flight rows.

    ``try_admit`` and ``release`` bracket a request's whole residency —
    admission to completion — so the budget bounds end-to-end server
    memory, not just the queue. Deterministic and lock-guarded (multiple
    submitters, one releaser).
    """

    def __init__(self, max_rows: int, max_bytes: int):
        if max_rows < 1 or max_bytes < 1:
            raise ValueError(
                f"backlog budget must be positive, got max_rows={max_rows} "
                f"max_bytes={max_bytes}")
        self.max_rows = max_rows
        self.max_bytes = max_bytes
        self.rows = 0
        self.bytes = 0
        self._lock = threading.Lock()

    def try_admit(self, rows: int, nbytes: int) -> bool:
        """Reserve budget; False (and no reservation) past either limit."""
        with self._lock:
            if self.rows + rows > self.max_rows:
                return False
            if self.bytes + nbytes > self.max_bytes:
                return False
            self.rows += rows
            self.bytes += nbytes
            return True

    def release(self, rows: int, nbytes: int) -> None:
        """Return budget reserved by a successful ``try_admit``."""
        with self._lock:
            self.rows -= rows
            self.bytes -= nbytes


class _Pending:
    __slots__ = ("x", "tenant", "arrival_s", "promise", "nbytes")

    def __init__(self, x, tenant, arrival_s, promise):
        self.x = x
        self.tenant = tenant
        self.arrival_s = arrival_s
        self.promise = promise
        self.nbytes = x.nbytes


@dataclasses.dataclass(frozen=True)
class _Inflight:
    device_scores: object
    requests: list
    bucket: int


class AsyncTMServer:
    """Continuous-batching TM scores server over one (session × bundle).

    >>> server = AsyncTMServer(session, bundle, engine="indexed",
    ...                        max_batch=32)
    >>> server.start()
    >>> promise = server.submit(x_row, tenant="acme")
    >>> result = promise.wait()     # ScoreResult | Overloaded
    >>> server.stop()

    The server is placement-blind exactly like the session it wraps: the
    AOT cache bakes the topology's shardings into its executables, so the
    same server code serves a laptop session or a data-sharded mesh.
    ``clock`` is injectable for deterministic tests.
    """

    def __init__(self, session, bundle, *, engine: str = "indexed",
                 max_batch: int = 32, aot: AOTBucketCache | None = None,
                 backlog_rows: int | None = None,
                 backlog_bytes: int = 64 << 20,
                 tenant_weights: dict[str, int] | None = None,
                 inflight: int = 2, clock=time.perf_counter):
        if aot is None:
            aot = AOTBucketCache(session, bundle, engines=(engine,),
                                 max_batch=max_batch)
        self.session = session
        self.bundle = bundle
        self.aot = aot
        self.engine = engine
        self.sizes = list(aot.bucket_sizes)
        self.n_features = aot.n_features
        top = self.sizes[-1]
        # default row budget: deep enough that a transient host stall (GIL
        # contention, a slow result copy) queues rather than rejects — at
        # high request rates the backlog must absorb tens of milliseconds
        # of arrivals — yet bounded so sustained overload turns into typed
        # rejections, not unbounded memory and latency
        self.backlog = Backlog(
            max_rows=backlog_rows if backlog_rows is not None
            else 32 * top * max(inflight, 1),
            max_bytes=backlog_bytes)
        self._clock = clock
        self._tenants = TenantQueues(weights=tenant_weights)
        self._stats: dict[str, TenantStats] = {}
        self._cond = threading.Condition()
        self._inflight: queue.Queue = queue.Queue()
        self._slots = threading.Semaphore(max(inflight, 1))
        self._stopping = False
        self._threads: list[threading.Thread] = []
        # dispatch-side counters (single writer: the dispatch thread)
        self.batches = 0
        self.rows_real = 0
        self.rows_padded = 0
        self.completed = 0

    # -- request side -------------------------------------------------------

    def submit(self, x_row, tenant: str = "default") -> Promise:
        """Admit one ``(n_features,)`` uint8 request row.

        Returns a promise resolving to ``ScoreResult`` — or, when the
        backlog budget is exhausted, one already resolved to a typed
        ``Overloaded`` (admission control; the request never queues).
        """
        x_row = np.ascontiguousarray(x_row, np.uint8)
        promise = Promise()
        arrival = self._clock()
        with self._cond:
            stats = self._stats.get(tenant)
            if stats is None:
                stats = self._stats[tenant] = TenantStats()
            if not self.backlog.try_admit(1, x_row.nbytes):
                stats.rejected += 1
                promise.resolve(Overloaded(
                    tenant=tenant, arrival_s=arrival,
                    backlog_rows=self.backlog.rows,
                    backlog_bytes=self.backlog.bytes,
                    max_rows=self.backlog.max_rows,
                    max_bytes=self.backlog.max_bytes))
                return promise
            stats.admitted += 1
            self._tenants.push(
                tenant, _Pending(x_row, tenant, arrival, promise))
            self._cond.notify()
        return promise

    # -- engine (each phase callable synchronously for tests) ---------------

    def form_batch(self) -> list:
        """Take up to a top bucket of pending rows (weighted round-robin)."""
        with self._cond:
            return self._tenants.take(self.sizes[-1])

    def dispatch(self, reqs: list) -> _Inflight:
        """Pad one request list to its bucket and dispatch through the AOT
        cache — asynchronous: returns device arrays, never blocks on
        compute."""
        k = len(reqs)
        b = bucket_for(k, self.sizes)
        xp = np.zeros((b, self.n_features), np.uint8)
        for i, r in enumerate(reqs):
            xp[i] = r.x
        dev = self.aot(xp, engine=self.engine, bucket=b)
        self.batches += 1
        self.rows_real += k
        self.rows_padded += b
        return _Inflight(device_scores=dev, requests=reqs, bucket=b)

    def complete(self, item: _Inflight) -> None:
        """Block on one in-flight batch, resolve its promises, release the
        backlog budget (per-tenant latency recorded here)."""
        host = np.asarray(item.device_scores)  # device sync happens here
        done = self._clock()
        nbytes = 0
        with self._cond:
            for i, r in enumerate(item.requests):
                r.promise.resolve(ScoreResult(
                    scores=host[i], tenant=r.tenant,
                    arrival_s=r.arrival_s, done_s=done))
                self._stats[r.tenant].record(done - r.arrival_s)
                nbytes += r.nbytes
            self.completed += len(item.requests)
        self.backlog.release(len(item.requests), nbytes)

    def step(self) -> int:
        """One synchronous dispatch+complete round (unit tests; also a
        valid single-threaded serving mode). Returns rows served."""
        reqs = self.form_batch()
        if not reqs:
            return 0
        self.complete(self.dispatch(reqs))
        return len(reqs)

    # -- threads ------------------------------------------------------------

    def _dispatch_loop(self) -> None:
        while True:
            # in-flight backpressure happens *before* batch formation, so
            # each freed slot's take() sees everything that arrived during
            # the completed compute window — one batch per window, not
            # several fragments (see the module docstring). Never blocks
            # under the lock.
            self._slots.acquire()
            with self._cond:
                while not self._stopping and not len(self._tenants):
                    self._cond.wait()
                if self._stopping and not len(self._tenants):
                    self._slots.release()
                    break
                reqs = self._tenants.take(self.sizes[-1])
            if reqs:
                self._inflight.put(self.dispatch(reqs))
            else:
                self._slots.release()
        self._inflight.put(None)  # sentinel: drains then stops the results

    def _result_loop(self) -> None:
        while True:
            item = self._inflight.get()
            if item is None:
                break
            self.complete(item)
            self._slots.release()

    def start(self) -> "AsyncTMServer":
        """Spawn the dispatch and result threads (idempotent)."""
        if self._threads:
            return self
        self._stopping = False
        self._threads = [
            threading.Thread(target=self._dispatch_loop,
                             name="tm-serve-dispatch", daemon=True),
            threading.Thread(target=self._result_loop,
                             name="tm-serve-result", daemon=True),
        ]
        for t in self._threads:
            t.start()
        return self

    def drain(self, timeout: float = 30.0) -> None:
        """Block until every admitted request has completed."""
        deadline = time.monotonic() + timeout
        while self.backlog.rows > 0:
            if time.monotonic() > deadline:
                raise TimeoutError(
                    f"{self.backlog.rows} rows still in flight after "
                    f"{timeout}s")
            time.sleep(0.001)

    def stop(self) -> None:
        """Serve out the remaining backlog, then join both threads."""
        if not self._threads:
            return
        with self._cond:
            self._stopping = True
            self._cond.notify_all()
        for t in self._threads:
            t.join()
        self._threads = []

    # -- reporting ----------------------------------------------------------

    def stats(self) -> dict:
        """Cumulative counters + per-tenant ledgers + AOT cache counters
        (snapshot; loadgen diffs consecutive snapshots per load step)."""
        with self._cond:
            per_tenant = {t: s.summary() for t, s in self._stats.items()}
            batches, rows_real = self.batches, self.rows_real
            rows_padded, completed = self.rows_padded, self.completed
        return {
            "batches": batches,
            "rows_real": rows_real,
            "rows_padded": rows_padded,
            "completed": completed,
            "backlog_rows": self.backlog.rows,
            "tenants": per_tenant,
            "aot": self.aot.counters(),
        }


class _JitBucketRunner:
    """``AOTBucketCache`` stand-in for the synchronous baseline server.

    Dispatches through the session's ordinary jit cache — compiled lazily
    per bucket during ``warmup``, which is exactly how the pre-§10 serve
    loop compiled. Mirrors the AOT cache's counter surface so the
    loadgen's hot-loop assert applies to the baseline too: the jit cache
    is likewise frozen once every declared bucket has warmed, because the
    server only ever pads to those buckets.
    """

    def __init__(self, session, bundle, *, engines=("indexed",),
                 bucket_sizes=None, max_batch: int = 32,
                 warmup: bool = True):
        if bucket_sizes is None:
            bucket_sizes = buckets(max_batch,
                                   min_batch=session.topology.data_shards)
        self.bucket_sizes = sorted({int(b) for b in bucket_sizes})
        self.engines = tuple(engines)
        self.n_features = session.cfg.n_features
        self._session = session
        self._bundle = bundle
        self.lowerings = 0
        self.hits = 0
        self.misses = 0
        self._compile_s: dict[str, dict[str, float]] = {}
        if warmup:
            self.warmup()

    def __call__(self, x, *, engine: str, bucket: int) -> jax.Array:
        """Dispatch one padded batch through ``session.scores`` (jit path,
        shape-keyed cache — a new shape would retrace, which warmup rules
        out by pre-touching every bucket)."""
        self.hits += 1
        return self._session.scores(self._bundle, jnp.asarray(x),
                                    engine=engine)

    def warmup(self) -> None:
        """Compile every (engine × bucket) through the jit cache and block,
        keeping compilation outside the timed loop like the old loop's
        warmup pass did. Excluded from the hit counter."""
        hits = self.hits
        for engine in self.engines:
            for b in self.bucket_sizes:
                t0 = time.perf_counter()
                x = np.zeros((b, self.n_features), np.uint8)
                jax.block_until_ready(self(x, engine=engine, bucket=b))
                self._compile_s.setdefault(engine, {})[str(b)] = round(
                    time.perf_counter() - t0, 4)
                self.lowerings += 1
        self.hits = hits

    def compile_report(self) -> dict:
        """Per-engine ``{bucket: seconds}`` first-call (compile) times,
        string-keyed like ``AOTBucketCache.compile_report``."""
        return {e: dict(t) for e, t in self._compile_s.items()}

    def counters(self) -> dict:
        """Same counter shape as ``AOTBucketCache.counters`` so loadgen's
        zero-compilations-in-the-hot-loop assert covers the baseline."""
        return {"engines": len(self.engines),
                "buckets": len(self.bucket_sizes),
                "entries": len(self.engines) * len(self.bucket_sizes),
                "lowerings": self.lowerings,
                "hits": self.hits,
                "misses": self.misses}


class SyncTMServer(AsyncTMServer):
    """The pre-§10 synchronous drain loop behind the modern submit surface
    — the measured baseline of ``BENCH_tm_serve.json``'s ``sustained_load``.

    One worker thread serialises every phase exactly like the loop
    ``launch/tm_serve.py`` used to run: take a batch → pad → jit dispatch →
    *block on device* → complete → repeat. Same admission control, same
    tenant fairness, same promises as ``AsyncTMServer`` — the only variable
    left between the two under the same open-loop load generator is the
    dispatch/compute overlap, which is exactly what the benchmark isolates.
    Buckets pre-compile through the jit cache at construction, so like the
    async server it never compiles inside the timed loop.
    """

    def __init__(self, session, bundle, *, engine: str = "indexed",
                 max_batch: int = 32, backlog_rows: int | None = None,
                 backlog_bytes: int = 64 << 20,
                 tenant_weights: dict[str, int] | None = None,
                 clock=time.perf_counter, warmup: bool = True):
        super().__init__(
            session, bundle, engine=engine, max_batch=max_batch,
            aot=_JitBucketRunner(session, bundle, engines=(engine,),
                                 max_batch=max_batch, warmup=warmup),
            backlog_rows=backlog_rows, backlog_bytes=backlog_bytes,
            tenant_weights=tenant_weights, inflight=1, clock=clock)

    def _serve_loop(self) -> None:
        while True:
            with self._cond:
                while not self._stopping and not len(self._tenants):
                    self._cond.wait()
                if self._stopping and not len(self._tenants):
                    return
                reqs = self._tenants.take(self.sizes[-1])
            if reqs:
                item = self.dispatch(reqs)
                jax.block_until_ready(item.device_scores)
                self.complete(item)

    def start(self) -> "SyncTMServer":
        """Spawn the single blocking serve thread (idempotent)."""
        if self._threads:
            return self
        self._stopping = False
        t = threading.Thread(target=self._serve_loop,
                             name="tm-serve-sync", daemon=True)
        self._threads = [t]
        t.start()
        return self
