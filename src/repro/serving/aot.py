"""AOT bucket cache: compile every padding bucket before the first request.

The MaxText MLPerf offline-inference recipe applied to TM serving: the
server declares its padding buckets up front, each bucket's scores graph is
``jit(...).lower(...).compile()``-d at startup through
``TMSession.lower_scores`` (explicit in/out shardings on a sharded session,
optional batch-operand donation), and the hot serving loop only ever calls
an already-compiled executable. Compile time is reported separately per
bucket, never inside the latency loop; a lookup for a shape that was not
pre-compiled raises ``AOTCacheMiss`` instead of silently tracing — zero
compilations inside the timed loop is an *assertable* property
(``counters()["lowerings"]`` is constant after construction).

Entries are keyed on ``(engine, bucket, session fingerprint)``: the
fingerprint covers config × resolved placement × kernel backend
(``TMSession.fingerprint``), so executables are never reused across
incompatible sessions.
"""
from __future__ import annotations

import dataclasses
import time

import jax
import numpy as np

from repro.core.api import resolve_donate
from repro.core.session import TMSession


def buckets(max_batch: int, min_batch: int = 1) -> list[int]:
    """Power-of-two padding buckets in [min_batch, max_batch].

    ``min_batch`` is the serving topology's data-shard count: every padded
    batch must divide over the mesh ``data`` axis, so a top bucket that is
    not a multiple of ``min_batch`` rounds *down* to one (the serve loop
    caps admission at the top bucket).
    """
    if min_batch > max_batch:
        raise ValueError(
            f"max_batch={max_batch} < data shards={min_batch}: every "
            "batch must divide over the data axis — raise max_batch or "
            "serve with fewer data shards")
    out = [min_batch]
    while out[-1] < max_batch:
        nxt = min(out[-1] * 2, max_batch)
        if nxt % min_batch:
            nxt = max(min_batch, (nxt // min_batch) * min_batch)
            if nxt == out[-1]:
                break
        out.append(nxt)
    return out


def bucket_for(n: int, sizes: list[int]) -> int:
    """Smallest bucket in ``sizes`` (ascending) holding ``n`` rows."""
    for b in sizes:
        if b >= n:
            return b
    return sizes[-1]


class AOTCacheMiss(KeyError):
    """A scores executable was requested for a shape that was never
    AOT-compiled — the serving invariant (no compilation in the hot loop)
    would be violated, so the lookup fails loudly instead of tracing."""


@dataclasses.dataclass(frozen=True)
class _Entry:
    compiled: object          # jax.stages.Compiled
    bind: object              # (compiled, x) -> device scores
    x_sharding: object | None
    lower_s: float
    compile_s: float


class AOTBucketCache:
    """Every (engine × padding bucket) scores executable, compiled up front.

    >>> cache = AOTBucketCache(session, bundle, engines=("indexed",),
    ...                        max_batch=32)
    >>> scores = cache(x_padded, engine="indexed", bucket=32)  # never traces

    ``__call__`` is the hot path: a dict lookup, an optional
    ``device_put`` onto the batch operand's compiled sharding, and the
    bound executable — it dispatches asynchronously (the caller blocks on
    the returned device array when it needs the values, which is what lets
    the dispatch thread race ahead of device compute).
    """

    def __init__(self, session: TMSession, bundle, *,
                 engines=("indexed",), bucket_sizes=None,
                 max_batch: int = 32, donate_x: bool | None = None,
                 warmup: bool = True):
        if bucket_sizes is None:
            bucket_sizes = buckets(max_batch,
                                   min_batch=session.topology.data_shards)
        self.bucket_sizes = sorted({int(b) for b in bucket_sizes})
        self.engines = tuple(engines)
        self.fingerprint = session.fingerprint()
        self.n_features = session.cfg.n_features
        self.lowerings = 0   # constant after __init__ — the hot-loop assert
        self.hits = 0
        self.misses = 0
        donate = resolve_donate(donate_x)
        self._entries: dict[tuple[str, int, str], _Entry] = {}
        for engine in self.engines:
            for b in self.bucket_sizes:
                t0 = time.perf_counter()
                low = session.lower_scores(bundle, b, engine=engine,
                                           donate_x=donate)
                self.lowerings += 1
                t1 = time.perf_counter()
                compiled = low.lowered.compile()
                t2 = time.perf_counter()
                self._entries[(engine, b, self.fingerprint)] = _Entry(
                    compiled=compiled, bind=low.bind,
                    x_sharding=low.x_sharding,
                    lower_s=t1 - t0, compile_s=t2 - t1)
        if warmup:
            self.warmup()

    def __call__(self, x, *, engine: str, bucket: int) -> jax.Array:
        """Dispatch one padded ``(bucket, n_features)`` batch through the
        pre-compiled executable; raises ``AOTCacheMiss`` for unknown keys
        (the cache is frozen at construction — by design nothing compiles
        here)."""
        entry = self._entries.get((engine, bucket, self.fingerprint))
        if entry is None:
            self.misses += 1
            raise AOTCacheMiss(
                f"no AOT executable for engine={engine!r} bucket={bucket} "
                f"fingerprint={self.fingerprint} (compiled buckets: "
                f"{self.bucket_sizes}, engines: {self.engines})")
        self.hits += 1
        if entry.x_sharding is not None:
            x = jax.device_put(x, entry.x_sharding)
        return entry.bind(entry.compiled, x)

    def warmup(self) -> None:
        """Run every executable once on zeros and block — first-dispatch
        lazy costs (transfer setup, executable load) are paid here, not in
        the timed loop. Warmup calls are excluded from the hit counter."""
        hits = self.hits
        for engine in self.engines:
            for b in self.bucket_sizes:
                x = np.zeros((b, self.n_features), np.uint8)
                jax.block_until_ready(self(x, engine=engine, bucket=b))
        self.hits = hits

    def compile_report(self) -> dict:
        """Per-engine ``{bucket: seconds}`` compile (and lowering) times.

        Bucket keys are *strings* deliberately — this lands in JSON, where
        int keys would be coerced anyway (docs/BENCH_SCHEMAS.md documents
        the string-keyed shape).
        """
        out = {}
        for (engine, b, _), e in sorted(self._entries.items(),
                                        key=lambda kv: (kv[0][0], kv[0][1])):
            out.setdefault(engine, {})[str(b)] = round(
                e.lower_s + e.compile_s, 4)
        return out

    def counters(self) -> dict:
        """Cache counters for benchmark records and the hot-loop assert:
        ``lowerings`` must equal ``buckets`` (one per key) and stay
        constant across serving; ``misses`` must stay 0."""
        return {"engines": len(self.engines),
                "buckets": len(self.bucket_sizes),
                "entries": len(self._entries),
                "lowerings": self.lowerings,
                "hits": self.hits,
                "misses": self.misses}
