"""Per-tenant weighted round-robin admission + latency accounting.

Production TM traffic is multi-tenant (ROADMAP: many small models, many
callers); a single FIFO lets one hot tenant monopolise every batch and
starve everyone else's tail latency. ``TenantQueues`` keeps one FIFO per
tenant and drains them weighted-round-robin: each pass over the tenant
ring lets tenant *t* contribute up to ``weight(t)`` rows, so a tenant
flooding the backlog gets at most its weighted share of each batch while
light tenants keep their rows flowing. The ring start rotates per ``take``
so no tenant owns the front of every batch.

Pure data structure — no threads, no clocks — so fairness is unit-testable
deterministically (tests/test_tm_serving.py drives a hot tenant against
cold ones and asserts interleaving). ``TenantStats`` is the per-tenant
ledger the server keeps next to it: admitted/rejected/served counts and
completion latencies, summarised into the per-tenant records of
``BENCH_tm_serve.json``'s ``sustained_load``.
"""
from __future__ import annotations

import dataclasses
from collections import deque

import numpy as np


@dataclasses.dataclass
class TenantStats:
    """Admission and completion ledger for one tenant."""

    admitted: int = 0
    rejected: int = 0
    served: int = 0
    latency_s: list = dataclasses.field(default_factory=list)

    def record(self, latency_s: float) -> None:
        """Count one completed request and its arrival→completion latency."""
        self.served += 1
        self.latency_s.append(latency_s)

    def summary(self) -> dict:
        """JSON-ready record: counts + p50/p95/p99 latency (ms)."""
        out = {"admitted": self.admitted, "rejected": self.rejected,
               "served": self.served}
        if self.latency_s:
            lat = np.asarray(self.latency_s) * 1e3
            p50, p95, p99 = np.percentile(lat, [50, 95, 99])
            out["latency_ms"] = {"p50": round(float(p50), 3),
                                 "p95": round(float(p95), 3),
                                 "p99": round(float(p99), 3),
                                 "mean": round(float(lat.mean()), 3)}
        return out


class TenantQueues:
    """Per-tenant FIFOs drained by weighted round-robin.

    ``weights`` maps tenant name → positive integer rows-per-pass
    (unlisted tenants get ``default_weight``). Not thread-safe by itself —
    the server serialises access under its own condition lock.
    """

    def __init__(self, weights: dict[str, int] | None = None,
                 default_weight: int = 1):
        if default_weight < 1:
            raise ValueError(f"default_weight must be >= 1, got "
                             f"{default_weight}")
        for t, w in (weights or {}).items():
            if w < 1:
                raise ValueError(f"weight for tenant {t!r} must be >= 1, "
                                 f"got {w}")
        self._weights = dict(weights or {})
        self._default = default_weight
        self._queues: dict[str, deque] = {}
        self._ring: list[str] = []  # tenant order, fixed at first push
        self._cursor = 0
        self._n = 0

    def weight(self, tenant: str) -> int:
        """Rows tenant may contribute per round-robin pass."""
        return self._weights.get(tenant, self._default)

    def push(self, tenant: str, item) -> None:
        """Append one item to the tenant's FIFO (admission already done)."""
        q = self._queues.get(tenant)
        if q is None:
            q = self._queues[tenant] = deque()
            self._ring.append(tenant)
        q.append(item)
        self._n += 1

    def __len__(self) -> int:
        """Total queued items across every tenant."""
        return self._n

    def tenants(self) -> tuple[str, ...]:
        """Every tenant seen so far, in ring order."""
        return tuple(self._ring)

    def take(self, max_items: int) -> list:
        """Drain up to ``max_items`` by weighted round-robin.

        Repeated passes over the tenant ring, each tenant contributing up
        to its weight per pass, until the batch is full or every queue is
        empty; FIFO order is preserved within a tenant. The starting
        tenant rotates across calls.
        """
        out: list = []
        if not self._ring:
            return out
        start = self._cursor
        self._cursor = (self._cursor + 1) % len(self._ring)
        while len(out) < max_items and self._n:
            took_any = False
            for off in range(len(self._ring)):
                tenant = self._ring[(start + off) % len(self._ring)]
                q = self._queues[tenant]
                k = min(self.weight(tenant), max_items - len(out), len(q))
                for _ in range(k):
                    out.append(q.popleft())
                self._n -= k
                took_any = took_any or k > 0
                if len(out) >= max_items:
                    break
            if not took_any:
                break
        return out
