"""Sharding policy: PartitionSpecs for params and activations.

Design (DESIGN.md §4): 2-D param sharding — every weight matrix has one dim
on ``model`` (TP) and one on ``data`` (FSDP); ``pod`` is pure DP. Activations:
batch on ("pod","data"); the residual stream is additionally sequence-sharded
on ``model`` between blocks (Megatron-SP) via ``with_sharding_constraint``.

Models are written sharding-agnostic and call ``policy.act(x, kind)`` /
take param specs from ``param_specs``. ``Policy.none()`` turns every
constraint into identity (CPU unit tests).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

# Mesh axis names (single pod: data/model; multi-pod adds a pure-DP "pod").
POD, DATA, MODEL = "pod", "data", "model"


def shard_map_compat(fn, *, mesh, in_specs, out_specs):
    """``jax.shard_map`` on new jax; ``jax.experimental.shard_map`` on 0.4.x.

    Replication checking is disabled either way (``check_vma`` new /
    ``check_rep`` old): these call sites assemble outputs whose replication
    the checker cannot prove (masked scatters, psum-combined partials).
    """
    sm = getattr(jax, "shard_map", None)
    if sm is not None:
        return sm(fn, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                  check_vma=False)
    from jax.experimental.shard_map import shard_map as sm_old
    return sm_old(fn, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                  check_rep=False)


def current_mesh():
    """The mesh in scope: ``jax.sharding.get_abstract_mesh()`` on new jax;
    the resource-env physical mesh (entered via ``launch.mesh.mesh_context``
    / ``with mesh:``) on 0.4.x."""
    get_am = getattr(jax.sharding, "get_abstract_mesh", None)
    if get_am is not None:
        return get_am()
    from jax._src import mesh as mesh_lib
    return mesh_lib.thread_resources.env.physical_mesh


@dataclasses.dataclass(frozen=True)
class Policy:
    """Activation/param sharding policy bound to mesh axis names."""

    active: bool = True
    batch_axes: tuple = (DATA,)          # axes sharding the batch dim
    model_axis: str | None = MODEL
    seq_shard_residual: bool = True      # Megatron-SP on the residual stream
    # decode_mode: weight-stationary serving. Activations' d_model dim is
    # sharded over `data`, so every weight matmul contracts a sharded dim →
    # partial dot + psum of ACTIVATION-sized tensors (KBs). Without it,
    # GSPMD all-gathers the FSDP-sharded weights every decode step —
    # measured 490 MB/layer collectives on qwen2-72b decode_32k
    # (EXPERIMENTS.md §Perf hillclimb A).
    decode_mode: bool = False

    @staticmethod
    def none() -> "Policy":
        return Policy(active=False)

    @staticmethod
    def for_mesh(mesh: jax.sharding.Mesh) -> "Policy":
        batch = (POD, DATA) if POD in mesh.axis_names else (DATA,)
        return Policy(active=True, batch_axes=batch, model_axis=MODEL)

    @property
    def b(self):
        """Batch-dim spec element (None when the batch can't be sharded)."""
        return self.batch_axes if self.batch_axes else None

    # -- activation constraints ------------------------------------------------
    def _constrain(self, x, spec):
        if not self.active:
            return x
        return jax.lax.with_sharding_constraint(x, spec)

    def act_btd(self, x):
        """(B, S, D) worked activations: batch sharded, d replicated on model
        (inputs/outputs of TP matmuls)."""
        if self.decode_mode:
            # batch replicated, d on data: transitions to/from the
            # batch-sharded attention path are activation-sized all-to-alls
            return self._constrain(x, P(None, None, DATA))
        return self._constrain(x, P(self.b, None, None))

    def act_btd_tp(self, x):
        """(B, S, D_shard) intermediate of a TP matmul: last dim on model."""
        return self._constrain(x, P(self.b, None, self.model_axis))

    def act_residual(self, x):
        """Residual stream between blocks: seq additionally on model (SP);
        decode (S=1): batch replicated, d on data (weight-stationary)."""
        if self.decode_mode:
            return self._constrain(x, P(None, None, DATA))
        if not self.seq_shard_residual:
            return self.act_btd(x)
        return self._constrain(x, P(self.b, self.model_axis, None))

    def act_heads(self, x):
        """(B, S, H, Dh): heads on model."""
        return self._constrain(x, P(self.b, None, self.model_axis, None))

    def kv_cache(self, x):
        """(B, S, H_kv, Dh) cache: batch on data, seq on model (flash-decode
        partial-softmax combines over model — DESIGN.md §4)."""
        return self._constrain(x, P(self.b, self.model_axis, None, None))

    def logits(self, x):
        """(B, S, V): vocab on model (pre-gather)."""
        return self._constrain(x, P(self.b, None, self.model_axis))


# ---------------------------------------------------------------------------
# Param partition rules — by leaf path regex, matching dims by name.
# Conventions: weights stored (in_dim, out_dim); stacked layer dim first.
# ---------------------------------------------------------------------------

# (regex over "/"-joined path, spec WITHOUT the stacked-layer dim)
_RULES: list[tuple[str, P]] = [
    # embeddings: (vocab, d) — vocab on model (TP), d on data (FSDP)
    (r"embed/tokens$", P(MODEL, DATA)),
    (r"lm_head$", P(DATA, MODEL)),       # (d, vocab)
    (r"pos_embed$", P(None, DATA)),
    # attention
    (r"attn/wq(/kernel)?$", P(DATA, MODEL)),
    (r"attn/wk(/kernel)?$", P(DATA, MODEL)),
    (r"attn/wv(/kernel)?$", P(DATA, MODEL)),
    (r"attn/wo(/kernel)?$", P(MODEL, DATA)),
    (r"attn/[bw][qkvo]_bias$", P(MODEL)),
    # dense mlp (swiglu/gelu)
    (r"mlp/w_(gate|up)(/kernel)?$", P(DATA, MODEL)),
    (r"mlp/w_down(/kernel)?$", P(MODEL, DATA)),
    # moe experts: (E, d, f) — f on model (TP inside expert), d on data
    (r"moe/shared/w_(gate|up)$", P(DATA, MODEL)),
    (r"moe/shared/w_down$", P(MODEL, DATA)),
    (r"moe/w_(gate|up)$", P(None, DATA, MODEL)),
    (r"moe/w_down$", P(None, MODEL, DATA)),
    (r"moe/router$", P(DATA, None)),
    (r"moe/shared_gate$", P(DATA)),
    # rwkv6 time/channel-mix projections: (d, d') → in on data, out on model
    (r"rwkv/cm/w_v$", P(MODEL, DATA)),    # (d_ff, d): f on model (TP out)
    (r"rwkv/.*w_(r|k|v|g)$", P(DATA, MODEL)),
    (r"rwkv/.*w_o$", P(MODEL, DATA)),
    # griffin recurrent block: branch projections + RG-LRU gates
    (r"rec/w_(y|x)$", P(DATA, MODEL)),
    (r"rec/w_o$", P(MODEL, DATA)),
    (r"rec/conv_w$", P(None, MODEL)),
    (r"rec/conv_b$", P(MODEL)),
    (r"rglru/w_[ai]$", P(DATA, MODEL)),
    (r"rglru/b_[ai]$", P(MODEL)),
    (r"rglru/lam$", P(MODEL)),
    # per-channel vectors (decays, mixes, norms over d_model): replicate
    (r".*(norm|scale|ln)[^/]*$", P()),
]


def _spec_for(path: str, ndim: int, stacked: bool) -> P:
    for pat, spec in _RULES:
        if re.search(pat, path):
            parts = tuple(spec)
            if stacked:
                parts = (None,) + parts
            # pad/truncate to ndim
            parts = parts[:ndim] + (None,) * max(0, ndim - len(parts))
            return P(*parts)
    return P()  # replicate by default (small vectors)


def param_specs(params: Any, stacked_prefixes: tuple[str, ...] = ("layers",)) -> Any:
    """Pytree of PartitionSpec mirroring ``params``.

    Leaves under a path starting with any of ``stacked_prefixes`` carry a
    leading stacked-layer dim that is never sharded.
    """
    flat = jax.tree_util.tree_flatten_with_path(params)
    specs = {}

    def keystr(kp):
        out = []
        for k in kp:
            if hasattr(k, "key"):
                out.append(str(k.key))
            elif hasattr(k, "idx"):
                out.append(str(k.idx))
            else:
                out.append(str(k))
        return "/".join(out)

    leaves, treedef = jax.tree_util.tree_flatten(params)
    paths = [keystr(kp) for kp, _ in flat[0]]
    out_leaves = []
    for path, leaf in zip(paths, [l for _, l in flat[0]]):
        stacked = any(path.startswith(p) for p in stacked_prefixes)
        out_leaves.append(_spec_for(path, leaf.ndim, stacked))
    return jax.tree_util.tree_unflatten(treedef, out_leaves)


def named_shardings(mesh: jax.sharding.Mesh, specs: Any) -> Any:
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        specs,
        is_leaf=lambda s: isinstance(s, P),
    )
