"""Train / prefill / decode step builders with sharding metadata.

``make_*_step`` return a ``StepBuild``: the pure step function plus the
PartitionSpecs for its inputs/outputs and the ShapeDtypeStructs needed to
``jit(...).lower()`` it without allocating anything — the contract the
multi-pod dry-run (launch/dryrun.py) and the roofline harness consume.

Training (DESIGN.md §4): microbatched gradient accumulation (lax.scan),
bf16 compute / fp32 masters+moments, optional gradient compression with
error feedback, AdamW + cosine schedule, z-loss, MoE aux loss.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeSpec
from repro.models.model import (
    Model,
    build,
    cache_specs,
    effective_cache_len,
    input_specs,
)
from repro.optim import adamw, compression, schedule as sched
from repro.sharding import DATA, MODEL, POD, Policy, param_specs

COMPUTE_DTYPE = jnp.bfloat16


@dataclasses.dataclass
class StepBuild:
    fn: Callable                 # (state/params, batch…) -> …
    arg_structs: tuple           # positional ShapeDtypeStructs for lower()
    in_specs: tuple              # matching PartitionSpecs
    out_specs: Any               # PartitionSpecs of outputs
    loop_dims: dict              # name -> full trip count (roofline §6)
    meta: dict


# ---------------------------------------------------------------------------
# Shared helpers
# ---------------------------------------------------------------------------


def batch_axes_for(global_batch: int, mesh) -> tuple:
    """Largest batch-sharding axis set the batch size divides."""
    if mesh is None:
        return ()
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    axes = [a for a in (POD, DATA) if a in sizes]
    prod = 1
    chosen = []
    for a in axes:
        if global_batch % (prod * sizes[a]) == 0:
            chosen.append(a)
            prod *= sizes[a]
    return tuple(chosen)


def _batch_spec(batch_tree, baxes):
    return jax.tree.map(lambda _: P(baxes), batch_tree)


def _cache_partition_specs(cache_tree, policy: Policy):
    """PartitionSpecs for a decode cache pytree by leaf-name rules."""
    flat = jax.tree_util.tree_flatten_with_path(cache_tree)
    leaves, treedef = jax.tree_util.tree_flatten(cache_tree)
    b = P(policy.batch_axes) if policy.batch_axes else P(None)
    bax = policy.batch_axes if policy.batch_axes else None
    m = policy.model_axis
    out = []
    for kp, leaf in flat[0]:
        path = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                        for k in kp)
        stacked = path.startswith("layers") or path.startswith("cross")
        nd = leaf.ndim - (1 if stacked else 0)
        if path.endswith("/k") or path.endswith("/v"):
            if "cross" in path:     # (B, S_enc, H, Dh): heads on model
                spec = (bax, None, m, None)[:nd]
            else:                    # (B, Hkv, S, Dh): seq on model
                spec = (bax, None, m, None)[:nd]
        elif path.endswith("/pos"):
            spec = (bax, m)[:nd]
        elif path.endswith("/wkv"):  # (B, H, Dk, Dv): Dv on model
            spec = (bax, None, None, m)[:nd]
        elif path.endswith("_shift"):  # (B, d)
            spec = (bax, m)[:nd]
        elif path.endswith("/h"):    # (B, d_rnn)
            spec = (bax, m)[:nd]
        elif path.endswith("/conv"):  # (B, 3, d_rnn)
            spec = (bax, None, m)[:nd]
        else:
            spec = (bax,) + (None,) * (nd - 1)
        if stacked:
            spec = (None,) + tuple(spec)
        out.append(P(*spec))
    return jax.tree_util.tree_unflatten(treedef, out)


def _xent(logits, labels, policy: Policy):
    """Stable token cross-entropy + z-loss; logits (B,S,V) fp32."""
    logits = policy.logits(logits)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = (lse - gold).mean()
    z_loss = 1e-4 * jnp.square(lse).mean()
    return nll + z_loss, nll


# ---------------------------------------------------------------------------
# Train step
# ---------------------------------------------------------------------------


def make_train_step(
    cfg: ModelConfig,
    shape: ShapeSpec,
    mesh=None,
    *,
    microbatches: int = 8,
    compress: str = "none",
    peak_lr: float = 3e-4,
    warmup_steps: int = 200,
    total_steps: int = 10_000,
    aux_coef: float = 0.01,
) -> StepBuild:
    model = build(cfg)
    policy = Policy.for_mesh(mesh) if mesh is not None else Policy.none()
    baxes = batch_axes_for(shape.global_batch // microbatches, mesh)
    if mesh is not None:
        policy = dataclasses.replace(policy, batch_axes=baxes,
                                     seq_shard_residual=cfg.sp_residual)

    def loss_fn(params32, mb):
        params = jax.tree.map(lambda x: x.astype(COMPUTE_DTYPE)
                              if x.dtype == jnp.float32 else x, params32)
        labels = mb.pop("labels")
        logits, aux = model.apply_train(policy, params, **mb)
        if cfg.family == "vlm":
            logits = logits[:, cfg.n_vision_tokens:]
        loss, nll = _xent(logits, labels, policy)
        return loss + aux_coef * aux, nll

    def train_step(state, batch):
        params, opt, ef = state["params"], state["opt"], state["ef"]
        # (B, …) -> (M, mb, …); re-pin the microbatch sharding explicitly
        def resh(x):
            x = x.reshape((microbatches, x.shape[0] // microbatches)
                          + x.shape[1:])
            if mesh is not None and baxes:
                x = jax.lax.with_sharding_constraint(x, P(None, baxes))
            return x
        mbs = jax.tree.map(resh, batch)

        grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

        def mb_body(acc, mb):
            (loss, nll), grads = grad_fn(params, dict(mb))
            acc = jax.tree.map(
                lambda a, g: a + g.astype(jnp.float32), acc, grads)
            return acc, (loss, nll)

        zero = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        if cfg.use_scan:
            grads, (losses, nlls) = jax.lax.scan(mb_body, zero, mbs)
        else:
            grads, ls, ns = zero, [], []
            for i in range(microbatches):
                grads, (l, n) = mb_body(
                    grads, jax.tree.map(lambda x: x[i], mbs))
                ls.append(l)
                ns.append(n)
            losses, nlls = jnp.stack(ls), jnp.stack(ns)
        grads = jax.tree.map(lambda g: g / microbatches, grads)
        grads, ef = compression.compress_grads(grads, ef, mode=compress)
        lr = sched.cosine_with_warmup(
            opt.step, peak_lr=peak_lr, warmup_steps=warmup_steps,
            total_steps=total_steps)
        params, opt, metrics = adamw.update(grads, opt, params, lr=lr)
        metrics.update(loss=losses.mean(), nll=nlls.mean())
        return {"params": params, "opt": opt, "ef": ef}, metrics

    # --- lowering metadata ---
    batch_structs = input_specs(cfg, shape)
    params_s = jax.eval_shape(
        functools.partial(_init_for, model, cfg), jax.random.key(0))
    state_struct = {
        "params": params_s,
        "opt": jax.eval_shape(adamw.init, params_s),
        "ef": jax.eval_shape(compression.init_error_feedback, params_s),
    }
    stacked = ("layers", "enc_layers")
    p_specs = param_specs(params_s, stacked_prefixes=stacked)
    state_specs = {
        "params": p_specs,
        "opt": adamw.AdamWState(step=P(), mu=p_specs, nu=p_specs),
        "ef": compression.ErrorFeedback(residual=p_specs),
    }
    batch_specs = _batch_spec(batch_structs, batch_axes_for(
        shape.global_batch, mesh))
    loop_dims = {"microbatches": microbatches, "layers": _layer_count(cfg)}
    if cfg.family == "encdec":
        loop_dims["enc_layers"] = cfg.n_enc_layers
    return StepBuild(
        fn=train_step,
        arg_structs=(state_struct, batch_structs),
        in_specs=(state_specs, batch_specs),
        out_specs=(state_specs, P()),
        loop_dims=loop_dims,
        meta=dict(kind="train", microbatches=microbatches),
    )


def _init_for(model: Model, cfg: ModelConfig, rng, max_positions=None):
    if cfg.family == "encdec":
        return model.init(rng, max_positions or 4096)
    return model.init(rng)


def _layer_count(cfg: ModelConfig) -> int:
    if cfg.family == "hybrid":
        pat = cfg.pattern or ("rec", "rec", "attn")
        return cfg.n_layers // len(pat)   # scan unit = one pattern group
    return cfg.n_layers


# ---------------------------------------------------------------------------
# Prefill / decode steps (serving)
# ---------------------------------------------------------------------------


def make_prefill_step(cfg: ModelConfig, shape: ShapeSpec, mesh=None) -> StepBuild:
    model = build(cfg)
    policy = Policy.for_mesh(mesh) if mesh is not None else Policy.none()
    baxes = batch_axes_for(shape.global_batch, mesh)
    if mesh is not None:
        policy = dataclasses.replace(policy, batch_axes=baxes)
    clen = effective_cache_len(cfg, shape)

    def prefill_step(params, batch):
        return model.prefill(policy, params, clen, **batch)

    params_s = _serve_params_struct(model, cfg, shape)
    batch_structs = input_specs(cfg, shape)
    p_specs = param_specs(params_s, stacked_prefixes=("layers", "enc_layers"))
    cache_s = cache_specs(cfg, shape)
    cache_p = _cache_partition_specs(cache_s, policy)
    return StepBuild(
        fn=prefill_step,
        arg_structs=(params_s, batch_structs),
        in_specs=(p_specs, _batch_spec(batch_structs, baxes)),
        out_specs=(P(baxes) if baxes else P(), cache_p),
        loop_dims={"layers": _layer_count(cfg),
                   **({"enc_layers": cfg.n_enc_layers}
                      if cfg.family == "encdec" else {})},
        meta=dict(kind="prefill", cache_len=clen),
    )


def make_decode_step(cfg: ModelConfig, shape: ShapeSpec, mesh=None) -> StepBuild:
    model = build(cfg)
    policy = Policy.for_mesh(mesh) if mesh is not None else Policy.none()
    baxes = batch_axes_for(shape.global_batch, mesh)
    if mesh is not None:
        policy = dataclasses.replace(policy, batch_axes=baxes,
                                     decode_mode=True)

    def decode_fn(params, caches, token, pos):
        return model.decode_step(policy, params, token, caches, pos)

    params_s = _serve_params_struct(model, cfg, shape)
    cache_s = cache_specs(cfg, shape)
    io = input_specs(cfg, shape)
    p_specs = param_specs(params_s, stacked_prefixes=("layers", "enc_layers"))
    cache_p = _cache_partition_specs(cache_s, policy)
    bspec = P(baxes) if baxes else P()
    return StepBuild(
        fn=decode_fn,
        arg_structs=(params_s, cache_s, io["token"], io["pos"]),
        in_specs=(p_specs, cache_p, bspec, bspec),
        out_specs=(bspec, cache_p),
        loop_dims={"layers": _layer_count(cfg)},
        meta=dict(kind="decode",
                  cache_len=effective_cache_len(cfg, shape)),
    )


def _serve_params_struct(model: Model, cfg: ModelConfig, shape: ShapeSpec):
    """Serving params: bf16 everywhere (fp32 masters live in training)."""
    max_pos = max(shape.seq_len, 4096) if cfg.family == "encdec" else None
    s = jax.eval_shape(functools.partial(_init_for, model, cfg,
                                         max_positions=max_pos),
                       jax.random.key(0))
    return jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(
            x.shape, COMPUTE_DTYPE if x.dtype == jnp.float32 else x.dtype),
        s)


def make_step(cfg: ModelConfig, shape: ShapeSpec, mesh=None, **kw) -> StepBuild:
    if shape.kind == "train":
        return make_train_step(cfg, shape, mesh, **kw)
    if shape.kind == "prefill":
        return make_prefill_step(cfg, shape, mesh)
    if shape.kind == "decode":
        return make_decode_step(cfg, shape, mesh)
    raise ValueError(shape.kind)
