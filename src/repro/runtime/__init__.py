"""runtime substrate."""
