"""TM training task for the fault-tolerant ``Trainer`` — single or sharded.

Glue that turns a ``TMConfig`` (+ optionally a mesh) into the four pieces
``runtime/trainer.py`` consumes:

  * ``step_fn(state, batch)`` — one jitted ``train_step`` over a TM bundle;
    the step RNG is ``fold_in(root_key, step)``, a pure function of the step
    index, so a restarted run consumes *identical* randomness;
  * ``state`` — ``{"bundle": TMBundle, "step": i32}``;
  * ``batcher`` — a deterministic (seed, step) ``TMBatcher`` stream;
  * ``to_ckpt`` / ``from_ckpt`` — checkpoint *views*: only the TA state and
    step counter persist; every engine cache is derived data, re-prepared on
    restore **on the current mesh**. That is what makes elastic
    reshard-on-restore work: shard-local cache layouts change shape with the
    clause-shard count, but the checkpoint never contains them.

Metrics per step: batch accuracy *before* the update (through a registry
engine), so the log doubles as an online-learning curve.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.core import TMConfig, TMState
from repro.core.api import (
    DEFAULT_ENGINE, TMBundle, bundle_predict, init_bundle, train_step_jit)
from repro.core.distributed import ShardedTM
from repro.core.types import init_tm
from repro.data.pipeline import TMBatcher


@dataclasses.dataclass
class TMTask:
    """Everything a ``Trainer`` needs to run a TM, plus the restore hooks."""

    step_fn: Callable
    state: dict[str, Any]
    batcher: TMBatcher
    to_ckpt: Callable
    from_ckpt: Callable


_predict_jit = jax.jit(bundle_predict, static_argnames=("engine",))


def make_tm_task(
    cfg: TMConfig,
    *,
    mesh=None,
    engines=None,
    batch: int = 32,
    seed: int = 0,
    data_seed: int = 7,
    parallel: bool = False,
    max_events: int = 4096,
    metrics_engine: str | None = None,
    metrics_every: int = 1,
) -> TMTask:
    """Build a TM training task; pass ``mesh`` for the clause-sharded path.

    ``metrics_engine`` defaults to ``DEFAULT_ENGINE`` when that engine is
    among the prepared ones, else to the first requested engine — the
    bundle only carries caches for ``engines``. ``metrics_every`` skips the
    pre-update accuracy pass on the other steps (set it to the trainer's
    ``log_every``: inference through the metrics engine costs a full eval
    per batch, wasted on steps whose metrics are never logged).
    """
    if metrics_engine is None:
        names = tuple(engines) if engines is not None else ()
        metrics_engine = (DEFAULT_ENGINE
                          if engines is None or DEFAULT_ENGINE in names
                          else names[0])
    root = jax.random.key(seed)
    batcher = TMBatcher(cfg.n_features, cfg.n_classes, batch, seed=data_seed)

    if mesh is None:
        bundle = init_bundle(cfg, engines=engines)
        sharded = None

        def predict(b: TMBundle, x):
            return _predict_jit(b, x, engine=metrics_engine)
    else:
        sharded = ShardedTM(cfg, mesh, engines=engines, parallel=parallel,
                            max_events=max_events)
        bundle = sharded.prepare(init_tm(cfg))

        def predict(b: TMBundle, x):
            # a sharded bundle's caches are shard-local layouts — they must
            # be read through the sharded scores path, never bundle_scores
            return jnp.argmax(sharded.scores(b, x, engine=metrics_engine), -1)

    def step_fn(state: dict, batch_: dict):
        b = state["bundle"]
        rng = jax.random.fold_in(root, state["step"])
        metrics = {}
        if (int(state["step"]) + 1) % metrics_every == 0:  # logged steps only
            pred = predict(b, batch_["x"])
            metrics = {"acc": jnp.mean(
                (pred == batch_["y"]).astype(jnp.float32))}
        if sharded is None:
            nb = train_step_jit(b, batch_["x"], batch_["y"], rng,
                                parallel=parallel, max_events=max_events)
        else:
            nb = sharded.train_step(b, batch_["x"], batch_["y"], rng)
        return {"bundle": nb, "step": state["step"] + 1}, metrics

    def to_ckpt(state: dict) -> dict:
        return {"ta_state": state["bundle"].state.ta_state,
                "step": state["step"]}

    def from_ckpt(loaded: dict, state: dict) -> dict:
        ta = TMState(ta_state=jnp.asarray(loaded["ta_state"]))
        if sharded is None:
            bundle = init_bundle(cfg, engines=engines, state=ta)
        else:
            bundle = sharded.prepare(ta)  # caches rebuilt on the current mesh
        return {"bundle": bundle, "step": jnp.asarray(loaded["step"])}

    state = {"bundle": bundle, "step": jnp.asarray(0, jnp.int32)}
    return TMTask(step_fn=step_fn, state=state, batcher=batcher,
                  to_ckpt=to_ckpt, from_ckpt=from_ckpt)
