"""TM training task for the fault-tolerant ``Trainer`` — any topology.

Glue that turns a ``TMConfig`` + a ``Topology`` (or an existing mesh) into
the four pieces ``runtime/trainer.py`` consumes, all driven through one
``TMSession`` (core/session.py) so the trainer never wires its own
prepare/scores/step paths:

  * ``step_fn(state, batch)`` — one session ``train_step`` over a TM bundle;
    the step RNG is ``fold_in(root_key, step)``, a pure function of the step
    index, so a restarted run consumes *identical* randomness;
  * ``state`` — ``{"bundle": TMBundle, "step": i32}``;
  * ``batcher`` — a deterministic (seed, step) ``TMBatcher`` stream;
  * ``to_ckpt`` / ``from_ckpt`` — checkpoint *views* in the versioned
    schema-v1 form (``checkpoint/tm_store.py``): TA state, step counter and
    the config fingerprint persist; every engine cache is derived data,
    rebuilt on restore **on the restoring session's topology**. That is what
    makes elastic reshard-on-restore work: shard-local cache layouts change
    shape with the clause-shard count, but the checkpoint never contains
    them — and the fingerprint catches restoring into a different config
    before any state is consumed.

Metrics per step: batch accuracy *before* the update (through a registry
engine), so the log doubles as an online-learning curve.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.checkpoint import tm_store
from repro.core import TMConfig, TMState
from repro.core.api import DEFAULT_ENGINE
from repro.core.session import TMSession, Topology
from repro.data.pipeline import TMBatcher


@dataclasses.dataclass
class TMTask:
    """Everything a ``Trainer`` needs to run a TM, plus the restore hooks."""

    step_fn: Callable
    state: dict[str, Any]
    batcher: TMBatcher
    to_ckpt: Callable
    from_ckpt: Callable
    session: TMSession


def make_tm_task(
    cfg: TMConfig,
    *,
    topology: Topology | None = None,
    mesh=None,
    engines=None,
    batch: int = 32,
    seed: int = 0,
    data_seed: int = 7,
    parallel: bool = False,
    max_events: int = 4096,
    backend: str | None = None,
    metrics_engine: str | None = None,
    metrics_every: int = 1,
) -> TMTask:
    """Build a TM training task on one session; any placement.

    Pass ``topology=Topology(clause_shards=..., data_shards=...)`` (or an
    explicit ``mesh`` to adopt) for the sharded path — the task itself is
    placement-transparent. ``backend`` pins the kernel backend the session's
    primitives resolve through (equivalent to ``Topology(backend=...)``;
    training and the metrics pass both go through the session, so the task
    never wires kernels itself).

    ``metrics_engine`` defaults to ``DEFAULT_ENGINE`` when that engine is
    among the maintained ones, else to the first requested engine — the
    bundle only carries caches for the session's engines. ``metrics_every``
    skips the pre-update accuracy pass on the other steps (set it to the
    trainer's ``log_every``: inference through the metrics engine costs a
    full eval per batch, wasted on steps whose metrics are never logged).
    """
    if backend is not None:
        topology = dataclasses.replace(topology or Topology(),
                                       backend=backend)
    session = TMSession(cfg, topology, mesh=mesh, engines=engines,
                        parallel=parallel, max_events=max_events)
    if metrics_engine is None:
        metrics_engine = (DEFAULT_ENGINE if DEFAULT_ENGINE in session.engines
                          else session.engines[0])
    root = jax.random.key(seed)
    batcher = TMBatcher(cfg.n_features, cfg.n_classes, batch, seed=data_seed)
    bundle = session.init_bundle()

    def step_fn(state: dict, batch_: dict):
        b = state["bundle"]
        rng = jax.random.fold_in(root, state["step"])
        metrics = {}
        if (int(state["step"]) + 1) % metrics_every == 0:  # logged steps only
            pred = session.predict(b, batch_["x"], engine=metrics_engine)
            metrics = {"acc": jnp.mean(
                (pred == batch_["y"]).astype(jnp.float32))}
        nb = session.train_step(b, batch_["x"], batch_["y"], rng)
        return {"bundle": nb, "step": state["step"] + 1}, metrics

    def to_ckpt(state: dict) -> dict:
        # always the unpadded global state: checkpoints are topology-free,
        # so a ragged clause layout (DESIGN.md §9) never leaks into one
        ta = session.unpad_state(state["bundle"].state).ta_state
        return tm_store.checkpoint_tree(cfg, ta, step=int(state["step"]))

    def from_ckpt(loaded: dict, state: dict) -> dict:
        tm_store.validate_meta(loaded, cfg, where="trainer checkpoint")
        ta = TMState(ta_state=jnp.asarray(loaded["ta_state"],
                                          cfg.state_dtype))
        # caches rebuilt on the restoring session's topology
        return {"bundle": session.prepare(ta),
                "step": jnp.asarray(loaded["step"], jnp.int32)}

    state = {"bundle": bundle, "step": jnp.asarray(0, jnp.int32)}
    return TMTask(step_fn=step_fn, state=state, batcher=batcher,
                  to_ckpt=to_ckpt, from_ckpt=from_ckpt, session=session)
