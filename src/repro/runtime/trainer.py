"""Fault-tolerant training driver.

Production behaviours implemented (and exercised by tests/test_runtime.py):

  * checkpoint/restart — periodic async checkpoints; on (re)start the driver
    restores the newest committed step and the data pipeline resumes the
    exact batch sequence (deterministic (seed, step) streams);
  * failure injection — ``failure_at`` raises mid-run to simulate a node
    loss; the test then restarts the driver and verifies bit-exact
    continuation vs an uninterrupted run;
  * straggler detection — per-step wall-time EWMA; steps slower than
    ``straggler_factor``× the watermark fire a callback (production: evict /
    re-shard; here: recorded + logged);
  * elastic restart — restore() takes the *current* mesh's shardings, so a
    2-pod checkpoint restores onto 1 pod (reshard-on-restore);
  * checkpoint views — ``to_ckpt``/``from_ckpt`` hooks let the train state
    carry derived data that should be *rebuilt*, not persisted: a TM bundle
    checkpoints only its TA state, and restore re-prepares every engine
    cache on the *current* mesh (runtime/tm_task.py) — which is exactly what
    makes reshard-on-restore work when the shard-local cache layouts change
    shape with the mesh.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional

import jax

from repro.checkpoint.checkpointer import Checkpointer


@dataclasses.dataclass
class TrainLoopConfig:
    total_steps: int
    ckpt_every: int = 50
    log_every: int = 10
    straggler_factor: float = 3.0
    straggler_warmup: int = 8
    failure_at: Optional[int] = None     # simulate a crash after this step


class SimulatedFailure(RuntimeError):
    pass


class Trainer:
    def __init__(self, *, step_fn, state, batcher, checkpointer: Checkpointer,
                 loop: TrainLoopConfig,
                 on_straggler: Optional[Callable[[int, float], None]] = None,
                 to_ckpt: Optional[Callable] = None,
                 from_ckpt: Optional[Callable] = None):
        self.step_fn = step_fn
        self.state = state
        self.batcher = batcher
        self.ckpt = checkpointer
        self.loop = loop
        self.on_straggler = on_straggler or (lambda s, t: None)
        # checkpoint views: persist to_ckpt(state); rebuild derived data on
        # restore via from_ckpt(loaded, current_state). Defaults: identity.
        self.to_ckpt = to_ckpt or (lambda state: state)
        self.from_ckpt = from_ckpt or (lambda loaded, state: loaded)
        self.metrics_log: list = []
        self.stragglers: list = []

    def restore_if_available(self, shardings=None) -> int:
        step = self.ckpt.latest_step()
        if step is None:
            return 0
        loaded = self.ckpt.restore(step, self.to_ckpt(self.state), shardings)
        self.state = self.from_ckpt(loaded, self.state)
        return step

    def run(self, start_step: Optional[int] = None) -> int:
        step = self.restore_if_available() if start_step is None else start_step
        ewma = None
        while step < self.loop.total_steps:
            batch = self.batcher(step)
            t0 = time.perf_counter()
            self.state, metrics = self.step_fn(self.state, batch)
            # block on the state too: steps whose metrics are cheap (or
            # skipped) must still charge the straggler timer for the update
            jax.block_until_ready((self.state, metrics))
            dt = time.perf_counter() - t0
            # straggler watermark
            if ewma is None:
                ewma = dt
            if step > self.loop.straggler_warmup and \
                    dt > self.loop.straggler_factor * ewma:
                self.stragglers.append((step, dt, ewma))
                self.on_straggler(step, dt)
            ewma = 0.9 * ewma + 0.1 * dt
            step += 1
            if step % self.loop.log_every == 0:
                self.metrics_log.append(
                    (step, {k: float(v) for k, v in metrics.items()}))
            if step % self.loop.ckpt_every == 0:
                self.ckpt.save(step, self.to_ckpt(self.state))
            if self.loop.failure_at is not None and step == self.loop.failure_at:
                self.ckpt.wait()
                raise SimulatedFailure(f"injected failure at step {step}")
        self.ckpt.save(step, self.to_ckpt(self.state), blocking=True)
        return step
