"""Tsetlin Machine forward pass and learning (paper §2).

Evaluation paths (all semantically identical; cross-validated in tests):
  * ``dense_clause_outputs``   — exhaustive evaluation, the paper's baseline.
  * packed words (kernels/backend.py) — dense over 32x packed words
    (VPU-friendly), XLA or Pallas body per ``cfg.backend``.
  * ``compact_eval`` (indexing.py) — gather over included literals only;
    work ∝ Σ clause lengths (the paper's sparsity).
  * ``indexed_scores`` (indexing.py) — the paper's falsification index.

Learning implements Type I / Type II feedback with explicit uniform draws
passed in, so the pure-numpy oracle in ``core/ref.py`` can be driven with the
*same* randomness and compared bit-exactly.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.types import (
    TMConfig,
    TMState,
    clause_polarity,
    include_mask,
    literals_from_input,
)

# ---------------------------------------------------------------------------
# Forward pass
# ---------------------------------------------------------------------------


def dense_clause_outputs(
    cfg: TMConfig, state: TMState, x: jax.Array, *, empty_output: int | None = None
) -> jax.Array:
    """Exhaustive clause evaluation. x: (B, o) {0,1} → (B, m, n) uint8.

    A clause is true iff no included literal is false:
      falsified(b, i, j) = ∃k: include[i,j,k] ∧ ¬literal[b,k].
    Implemented as an integer matmul (false-literal count per clause) so the
    dense baseline is itself vectorised — the paper's C baseline is a tight
    loop; an un-vectorised JAX loop would strawman it.
    """
    lit = literals_from_input(x)                      # (B, 2o)
    inc = include_mask(cfg, state)                    # (m, n, 2o)
    false_lit = (1 - lit).astype(jnp.float32)         # (B, 2o)
    # count of included-and-false literals per clause
    counts = jnp.einsum("bk,mnk->bmn", false_lit, inc.astype(jnp.float32))
    out = (counts < 0.5).astype(jnp.uint8)            # (B, m, n)
    empty_output = cfg.empty_clause_output if empty_output is None else empty_output
    if empty_output == 0:
        empty = ~jnp.any(inc, axis=-1)                # (m, n)
        out = out * (1 - empty.astype(jnp.uint8))[None]
    return out


def clause_votes(cfg: TMConfig, clause_out: jax.Array) -> jax.Array:
    """(B, m, n) clause outputs → (B, m) polarity-signed vote sums (Eq. 3)."""
    pol = clause_polarity(cfg)                        # (n,)
    return jnp.einsum("bmn,n->bm", clause_out.astype(jnp.int32), pol)


def scores(cfg: TMConfig, state: TMState, x: jax.Array) -> jax.Array:
    """(B, m) class scores via the dense path."""
    return clause_votes(cfg, dense_clause_outputs(cfg, state, x))


def predict(cfg: TMConfig, state: TMState, x: jax.Array) -> jax.Array:
    """(B,) argmax class (Eq. 3)."""
    return jnp.argmax(scores(cfg, state, x), axis=-1)


# The packed-word evaluation bodies (XLA reference + Pallas kernel) live in
# kernels/backend.py — the packed engine resolves them per cfg.backend, so
# this module carries only the dense baseline and the learning semantics.


# ---------------------------------------------------------------------------
# Learning: Type I / Type II feedback (paper §2, Granmo 2018 semantics)
# ---------------------------------------------------------------------------


class FeedbackRands(NamedTuple):
    """Uniform draws consumed by one class-round of feedback.

    Passing these explicitly makes the update a deterministic function, so
    the numpy oracle can replay identical randomness.
    """

    clause_gate: jax.Array  # (n,)      uniforms vs update probability p
    type_i: jax.Array       # (n, 2o)   uniforms vs 1/s and (s-1)/s


def draw_feedback_rands(cfg: TMConfig, rng: jax.Array) -> FeedbackRands:
    k1, k2 = jax.random.split(rng)
    return FeedbackRands(
        clause_gate=jax.random.uniform(k1, (cfg.n_clauses,)),
        type_i=jax.random.uniform(k2, (cfg.n_clauses, cfg.n_literals)),
    )


def _slice_rands(rands: FeedbackRands, start: jax.Array,
                 n_local: int) -> FeedbackRands:
    """Clause-shard slice of a *full* draw (clause-sharded learning).

    Every shard materialises the identical full-size draw and takes its own
    row block — the only scheme that keeps sharded learning bit-exact with
    the single-device path (per-shard draws would consume different keys).

    The row indices clamp into the draw instead of dynamic-slicing it, so a
    *padded* slice (ragged data×clause sub-slices, DESIGN.md §9) whose tail
    rows fall past ``n_clauses`` still reads the exact draw rows for its
    real clauses; the clamped duplicates land only on padding rows, whose
    updates are masked out (``clause_mask``).
    """
    idx = jnp.clip(start + jnp.arange(n_local),
                   0, rands.clause_gate.shape[0] - 1)
    return FeedbackRands(
        clause_gate=jnp.take(rands.clause_gate, idx, axis=0),
        type_i=jnp.take(rands.type_i, idx, axis=0),
    )


def _round_clause_outputs(cfg: TMConfig, ta_row: jax.Array,
                          lit: jax.Array, mode: str) -> jax.Array:
    """(n,) uint8 clause outputs of one class row (learning semantics:
    empty clauses → 1), through the backend-resolved evaluation body.

    ``mode`` is a *concrete* backend (``kernels/backend.resolve_backend``).
    The XLA body is the dense float-einsum falsification count; the Pallas
    body packs the row's include mask on the fly (a cheap VPU reshape-sum)
    and runs the bit-packed clause-output kernel — the first stage of the
    fused training round, so the (n, 2o) include mask never feeds a dense
    einsum and the clause outputs stream straight into the ``ta_update``
    kernel. Both bodies are bit-exact (same falsification predicate).
    """
    include = ta_row > cfg.n_states
    if mode == "xla":
        false_cnt = jnp.einsum(
            "k,nk->n", (1 - lit).astype(jnp.float32),
            include.astype(jnp.float32))
        return (false_cnt < 0.5).astype(jnp.uint8)
    from repro.core.bitpack import pack_bits
    from repro.kernels import backend as kbackend
    outputs = kbackend.resolve("clause_outputs", mode)
    inc_packed = pack_bits(include.astype(jnp.uint8))[None]   # (1, n, W)
    lit_packed = pack_bits(lit.astype(jnp.uint8)[None])       # (1, W)
    return outputs(inc_packed, lit_packed)[0, 0].astype(jnp.uint8)


def _class_round(
    cfg: TMConfig,
    ta_row: jax.Array,       # (n, 2o) — states of one class (or a clause shard)
    lit: jax.Array,          # (2o,)
    rands: FeedbackRands,
    positive_round: jax.Array,  # scalar bool — True: target-class round
    *,
    pol: jax.Array | None = None,   # (n,) ±1 — pass the local slice when sharded
    # mesh axes the votes psum over: the clause axis, or (batch axes + clause
    # axis) when the sequential path additionally splits clauses over the
    # data axes (hierarchical data×clause sharding)
    axis_name: str | tuple[str, ...] | None = None,
    clause_mask: jax.Array | None = None,  # (n,) bool — False rows frozen
) -> jax.Array:
    """One feedback round for one class; returns updated (n, 2o) states.

    Clause-sharded learning (core/distributed.py) calls this with the local
    ``ta_row``/``rands``/``pol`` slices and the mesh clause ``axis_name``: the
    per-class vote is the *only* cross-shard quantity (one psum — the vote
    all-reduce of the Massively Parallel TM architecture); Type I/II feedback
    is clause-local given that vote.

    ``clause_mask`` marks the rows that are *real* clauses: ragged shard
    slices (DESIGN.md §9) pad their clause axis, and a padding row must stay
    bit-identical through the round — it is excluded from the update gate
    (``active``), so both feedback bodies apply a zero delta. Its vote
    contribution is already zero by the sign-0 polarity padding convention,
    so the mask never touches the vote sum.

    Both halves of the round resolve through the kernel backend registry
    (``cfg.backend``): clause evaluation (``clause_outputs``) and feedback
    application (``ta_update``). On the Pallas backends this is the fused
    training round — packed-word clause outputs piped into the ``ta_update``
    kernel with only the scalar vote in between, bit-exact with the XLA
    bodies (tests/test_kernel_backends.py pins it in both learning modes).
    """
    from repro.kernels import backend as kbackend

    mode = kbackend.resolve_backend(cfg.backend)
    clause_out = _round_clause_outputs(cfg, ta_row, lit, mode)
    if pol is None:
        pol = clause_polarity(cfg)
    t = float(cfg.threshold)
    vote_sum = jnp.sum(clause_out.astype(jnp.int32) * pol)
    if axis_name is not None:
        vote_sum = jax.lax.psum(vote_sum, axis_name)
    votes = jnp.clip(vote_sum, -t, t)
    p = jnp.where(positive_round, (t - votes) / (2 * t), (t + votes) / (2 * t))
    active = rands.clause_gate < p                    # (n,)
    if clause_mask is not None:
        active = active & clause_mask                 # padding rows frozen

    pos_pol = pol > 0
    # target round: positive clauses→Type I, negative→Type II; swapped otherwise
    gets_type_i = jnp.where(positive_round, pos_pol, ~pos_pol)

    apply_feedback = kbackend.resolve("ta_update", mode)
    new_row = apply_feedback(
        ta_row.astype(jnp.int16), lit, clause_out, gets_type_i, active,
        rands.type_i, n_states=cfg.n_states, s=cfg.s,
        boost_true_positive=cfg.boost_true_positive)
    return new_row.astype(cfg.state_dtype)


def update_sample(
    cfg: TMConfig,
    state: TMState,
    x: jax.Array,        # (o,)
    y: jax.Array,        # () int
    rng: jax.Array,
    *,
    pol: jax.Array | None = None,
    axis_name: str | tuple[str, ...] | None = None,
    clause_start: jax.Array | None = None,
    clause_mask: jax.Array | None = None,
) -> TMState:
    """One online update (the paper's per-sample learning).

    Target class receives a positive round; one uniformly drawn *other*
    class receives a negative round (standard multiclass TM scheme).

    When ``state`` holds only a clause shard, pass the shard's polarity
    slice ``pol``, the mesh clause ``axis_name`` (vote psum) and the shard's
    global ``clause_start`` (rand slicing) — every shard draws the identical
    full-size randomness and consumes its own rows, so the sharded update is
    bit-exact with the single-device one. ``clause_mask`` (n,) freezes
    padding rows of a ragged slice (see ``_class_round``).
    """
    lit = literals_from_input(x)
    k_neg, k_a, k_b = jax.random.split(rng, 3)
    # sample negative class ≠ y
    neg = jax.random.randint(k_neg, (), 0, cfg.n_classes - 1)
    neg = jnp.where(neg >= y, neg + 1, neg)

    ta = state.ta_state
    rands_a = draw_feedback_rands(cfg, k_a)
    rands_b = draw_feedback_rands(cfg, k_b)
    if clause_start is not None:
        n_local = ta.shape[1]
        rands_a = _slice_rands(rands_a, clause_start, n_local)
        rands_b = _slice_rands(rands_b, clause_start, n_local)
    row_pos = _class_round(cfg, ta[y], lit, rands_a, jnp.asarray(True),
                           pol=pol, axis_name=axis_name,
                           clause_mask=clause_mask)
    ta = ta.at[y].set(row_pos)
    row_neg = _class_round(cfg, ta[neg], lit, rands_b, jnp.asarray(False),
                           pol=pol, axis_name=axis_name,
                           clause_mask=clause_mask)
    ta = ta.at[neg].set(row_neg)
    return TMState(ta_state=ta)


def update_batch_sequential(
    cfg: TMConfig, state: TMState, xs: jax.Array, ys: jax.Array,
    rng: jax.Array, *,
    pol: jax.Array | None = None,
    axis_name: str | tuple[str, ...] | None = None,
    clause_start: jax.Array | None = None,
    mask: jax.Array | None = None,
    clause_mask: jax.Array | None = None,
) -> TMState:
    """Faithful online learning over a batch: lax.scan of per-sample updates.

    Sharded mode (kwargs set): the *full* batch is scanned on every clause
    shard — online learning is sequential in samples by definition — with one
    vote psum per class round as the only collective.

    ``mask`` (B,) bool marks valid samples: masked-out rows consume their
    randomness (so padded and unpadded streams stay key-aligned) but apply no
    state update — the padding contract for fixed-shape trailing batches.
    ``clause_mask`` (n,) bool marks valid *clause rows*: the transpose
    contract for ragged shard slices (padding rows frozen, DESIGN.md §9).
    """
    keys = jax.random.split(rng, xs.shape[0])

    def body(st, inp):
        x, y, k, m = inp
        new = update_sample(cfg, st, x, y, k, pol=pol, axis_name=axis_name,
                            clause_start=clause_start,
                            clause_mask=clause_mask)
        return TMState(ta_state=jnp.where(m, new.ta_state, st.ta_state)), None

    valid = jnp.ones(xs.shape[0], bool) if mask is None else mask
    out, _ = jax.lax.scan(body, state, (xs, ys, keys, valid))
    return out


def update_batch_parallel(
    cfg: TMConfig, state: TMState, xs: jax.Array, ys: jax.Array,
    rng: jax.Array, *,
    pol: jax.Array | None = None,
    axis_name: str | tuple[str, ...] | None = None,
    clause_start: jax.Array | None = None,
    batch_axes: tuple[str, ...] = (),
    batch_start: jax.Array | None = None,
    batch_total: int | None = None,
    mask: jax.Array | None = None,
    clause_mask: jax.Array | None = None,
) -> TMState:
    """Beyond-paper: batch-parallel update (deltas computed vs the *same*
    pre-batch state, then summed). An approximation of online learning —
    documented in DESIGN.md; used for throughput-oriented training.

    Sharded mode additionally shards the *batch*: ``xs`` holds this data
    shard's slice of a ``batch_total``-sized global batch starting at
    ``batch_start``; per-sample keys are the global split sliced to match
    (bit-exact with the single-device split), and the summed deltas are
    psum'd over ``batch_axes`` before the clip. ``mask`` (B,) bool zeroes
    the deltas of padded samples (randomness still consumed per row);
    ``clause_mask`` (n,) bool zeroes the deltas of padded clause rows
    (ragged shard slices, DESIGN.md §9).
    """
    if batch_total is None:
        keys = jax.random.split(rng, xs.shape[0])
    else:
        # global key stream, local slice — identical keys per global sample
        kd = jax.random.key_data(jax.random.split(rng, batch_total))
        kd = jax.lax.dynamic_slice_in_dim(kd, batch_start, xs.shape[0], 0)
        keys = jax.random.wrap_key_data(kd)

    def one(x, y, k):
        new = update_sample(cfg, state, x, y, k, pol=pol, axis_name=axis_name,
                            clause_start=clause_start,
                            clause_mask=clause_mask)
        return (new.ta_state.astype(jnp.int32) - state.ta_state.astype(jnp.int32))

    deltas = jax.vmap(one)(xs, ys, keys)
    if mask is not None:
        deltas = jnp.where(mask[:, None, None, None], deltas, 0)
    deltas = deltas.sum(axis=0)
    if batch_axes:
        deltas = jax.lax.psum(deltas, batch_axes)
    ta = jnp.clip(
        state.ta_state.astype(jnp.int32) + deltas, 1, 2 * cfg.n_states
    ).astype(cfg.state_dtype)
    return TMState(ta_state=ta)


def accuracy(cfg: TMConfig, state: TMState, xs: jax.Array, ys: jax.Array) -> jax.Array:
    return jnp.mean((predict(cfg, state, xs) == ys).astype(jnp.float32))
