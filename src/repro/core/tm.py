"""Tsetlin Machine forward pass and learning (paper §2).

Evaluation paths (all semantically identical; cross-validated in tests):
  * ``dense_clause_outputs``   — exhaustive evaluation, the paper's baseline.
  * packed words (kernels/backend.py) — dense over 32x packed words
    (VPU-friendly), XLA or Pallas body per ``cfg.backend``.
  * ``compact_eval`` (indexing.py) — gather over included literals only;
    work ∝ Σ clause lengths (the paper's sparsity).
  * ``indexed_scores`` (indexing.py) — the paper's falsification index.

Learning implements Type I / Type II feedback with explicit uniform draws
passed in, so the pure-numpy oracle in ``core/ref.py`` can be driven with the
*same* randomness and compared bit-exactly.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.types import (
    TMConfig,
    TMState,
    clause_polarity,
    include_mask,
    literals_from_input,
)

# ---------------------------------------------------------------------------
# Forward pass
# ---------------------------------------------------------------------------


def dense_clause_outputs(
    cfg: TMConfig, state: TMState, x: jax.Array, *, empty_output: int | None = None
) -> jax.Array:
    """Exhaustive clause evaluation. x: (B, o) {0,1} → (B, m, n) uint8.

    A clause is true iff no included literal is false:
      falsified(b, i, j) = ∃k: include[i,j,k] ∧ ¬literal[b,k].
    Implemented as an integer matmul (false-literal count per clause) so the
    dense baseline is itself vectorised — the paper's C baseline is a tight
    loop; an un-vectorised JAX loop would strawman it.
    """
    lit = literals_from_input(x)                      # (B, 2o)
    inc = include_mask(cfg, state)                    # (m, n, 2o)
    false_lit = (1 - lit).astype(jnp.float32)         # (B, 2o)
    # count of included-and-false literals per clause
    counts = jnp.einsum("bk,mnk->bmn", false_lit, inc.astype(jnp.float32))
    out = (counts < 0.5).astype(jnp.uint8)            # (B, m, n)
    empty_output = cfg.empty_clause_output if empty_output is None else empty_output
    if empty_output == 0:
        empty = ~jnp.any(inc, axis=-1)                # (m, n)
        out = out * (1 - empty.astype(jnp.uint8))[None]
    return out


def clause_votes(cfg: TMConfig, clause_out: jax.Array) -> jax.Array:
    """(B, m, n) clause outputs → (B, m) polarity-signed vote sums (Eq. 3)."""
    pol = clause_polarity(cfg)                        # (n,)
    return jnp.einsum("bmn,n->bm", clause_out.astype(jnp.int32), pol)


def scores(cfg: TMConfig, state: TMState, x: jax.Array) -> jax.Array:
    """(B, m) class scores via the dense path."""
    return clause_votes(cfg, dense_clause_outputs(cfg, state, x))


def predict(cfg: TMConfig, state: TMState, x: jax.Array) -> jax.Array:
    """(B,) argmax class (Eq. 3)."""
    return jnp.argmax(scores(cfg, state, x), axis=-1)


# The packed-word evaluation bodies (XLA reference + Pallas kernel) live in
# kernels/backend.py — the packed engine resolves them per cfg.backend, so
# this module carries only the dense baseline and the learning semantics.


# ---------------------------------------------------------------------------
# Learning: Type I / Type II feedback (paper §2, Granmo 2018 semantics)
# ---------------------------------------------------------------------------


class FeedbackRands(NamedTuple):
    """Uniform draws consumed by one class-round of feedback.

    Passing these explicitly makes the update a deterministic function, so
    the numpy oracle can replay identical randomness.
    """

    clause_gate: jax.Array  # (n,)      uniforms vs update probability p
    type_i: jax.Array       # (n, 2o)   uniforms vs 1/s and (s-1)/s


def draw_feedback_rands(cfg: TMConfig, rng: jax.Array) -> FeedbackRands:
    """Draw one class-round's full-size uniforms from ``rng``."""
    k1, k2 = jax.random.split(rng)
    return FeedbackRands(
        clause_gate=jax.random.uniform(k1, (cfg.n_clauses,)),
        type_i=jax.random.uniform(k2, (cfg.n_clauses, cfg.n_literals)),
    )


def _slice_rands(rands: FeedbackRands, start: jax.Array,
                 n_local: int) -> FeedbackRands:
    """Clause-shard slice of a *full* draw (clause-sharded learning).

    Every shard materialises the identical full-size draw and takes its own
    row block — the only scheme that keeps sharded learning bit-exact with
    the single-device path (per-shard draws would consume different keys).

    The row indices clamp into the draw instead of dynamic-slicing it, so a
    *padded* slice (ragged data×clause sub-slices, DESIGN.md §9) whose tail
    rows fall past ``n_clauses`` still reads the exact draw rows for its
    real clauses; the clamped duplicates land only on padding rows, whose
    updates are masked out (``clause_mask``).
    """
    idx = jnp.clip(start + jnp.arange(n_local),
                   0, rands.clause_gate.shape[0] - 1)
    return FeedbackRands(
        clause_gate=jnp.take(rands.clause_gate, idx, axis=0),
        type_i=jnp.take(rands.type_i, idx, axis=0),
    )


def _round_clause_outputs(cfg: TMConfig, ta_row: jax.Array,
                          lit: jax.Array, mode: str) -> jax.Array:
    """(n,) uint8 clause outputs of one class row (learning semantics:
    empty clauses → 1), through the backend-resolved evaluation body.

    ``mode`` is a *concrete* backend (``kernels/backend.resolve_backend``).
    The XLA body is the dense float-einsum falsification count; the Pallas
    body packs the row's include mask on the fly (a cheap VPU reshape-sum)
    and runs the bit-packed clause-output kernel — the first stage of the
    fused training round, so the (n, 2o) include mask never feeds a dense
    einsum and the clause outputs stream straight into the ``ta_update``
    kernel. Both bodies are bit-exact (same falsification predicate).
    """
    include = ta_row > cfg.n_states
    if mode == "xla":
        false_cnt = jnp.einsum(
            "k,nk->n", (1 - lit).astype(jnp.float32),
            include.astype(jnp.float32))
        return (false_cnt < 0.5).astype(jnp.uint8)
    from repro.core.bitpack import pack_bits
    from repro.kernels import backend as kbackend
    outputs = kbackend.resolve("clause_outputs", mode)
    inc_packed = pack_bits(include.astype(jnp.uint8))[None]   # (1, n, W)
    lit_packed = pack_bits(lit.astype(jnp.uint8)[None])       # (1, W)
    return outputs(inc_packed, lit_packed)[0, 0].astype(jnp.uint8)


def _class_round(
    cfg: TMConfig,
    ta_row: jax.Array,       # (n, 2o) — states of one class (or a clause shard)
    lit: jax.Array,          # (2o,)
    rands: FeedbackRands,
    positive_round: jax.Array,  # scalar bool — True: target-class round
    *,
    pol: jax.Array | None = None,   # (n,) ±1 — pass the local slice when sharded
    # mesh axes the votes psum over: the clause axis, or (batch axes + clause
    # axis) when the sequential path additionally splits clauses over the
    # data axes (hierarchical data×clause sharding)
    axis_name: str | tuple[str, ...] | None = None,
    clause_mask: jax.Array | None = None,  # (n,) bool — False rows frozen
    stale_vote: jax.Array | None = None,   # scalar — remote votes, K-step old
) -> jax.Array:
    """One feedback round for one class; returns updated (n, 2o) states.

    Clause-sharded learning (core/distributed.py) calls this with the local
    ``ta_row``/``rands``/``pol`` slices and the mesh clause ``axis_name``: the
    per-class vote is the *only* cross-shard quantity (one psum — the vote
    all-reduce of the Massively Parallel TM architecture); Type I/II feedback
    is clause-local given that vote.

    Asynchronous sharded learning (DESIGN.md §11) passes ``stale_vote``
    instead of ``axis_name``: the round reads ``live local votes +
    stale_vote`` — the remote shards' contribution from the last K-step
    refresh — and performs **no collective at all**. The randomness-draw
    discipline is untouched (draws happen in the caller either way), so a
    sync and an async round consume identical keys; only the vote value the
    feedback probability reads differs. In this mode the round additionally
    returns its *local* partial vote sum, which the caller records into the
    ``VoteAccumulator`` write buffer.

    ``clause_mask`` marks the rows that are *real* clauses: ragged shard
    slices (DESIGN.md §9) pad their clause axis, and a padding row must stay
    bit-identical through the round — it is excluded from the update gate
    (``active``), so both feedback bodies apply a zero delta. Its vote
    contribution is already zero by the sign-0 polarity padding convention,
    so the mask never touches the vote sum.

    Both halves of the round resolve through the kernel backend registry
    (``cfg.backend``): clause evaluation (``clause_outputs``) and feedback
    application (``ta_update``). On the Pallas backends this is the fused
    training round — packed-word clause outputs piped into the ``ta_update``
    kernel with only the scalar vote in between, bit-exact with the XLA
    bodies (tests/test_kernel_backends.py pins it in both learning modes).
    """
    from repro.kernels import backend as kbackend

    mode = kbackend.resolve_backend(cfg.backend)
    clause_out = _round_clause_outputs(cfg, ta_row, lit, mode)
    if pol is None:
        pol = clause_polarity(cfg)
    t = float(cfg.threshold)
    vote_local = jnp.sum(clause_out.astype(jnp.int32) * pol)
    if stale_vote is not None:  # async: live local + K-step-stale remote
        vote_sum = vote_local + stale_vote
    else:
        vote_sum = vote_local
        if axis_name is not None:
            vote_sum = jax.lax.psum(vote_sum, axis_name)
    votes = jnp.clip(vote_sum, -t, t)
    p = jnp.where(positive_round, (t - votes) / (2 * t), (t + votes) / (2 * t))
    active = rands.clause_gate < p                    # (n,)
    if clause_mask is not None:
        active = active & clause_mask                 # padding rows frozen

    pos_pol = pol > 0
    # target round: positive clauses→Type I, negative→Type II; swapped otherwise
    gets_type_i = jnp.where(positive_round, pos_pol, ~pos_pol)

    apply_feedback = kbackend.resolve("ta_update", mode)
    new_row = apply_feedback(
        ta_row.astype(jnp.int16), lit, clause_out, gets_type_i, active,
        rands.type_i, n_states=cfg.n_states, s=cfg.s,
        boost_true_positive=cfg.boost_true_positive)
    new_row = new_row.astype(cfg.state_dtype)
    if stale_vote is not None:
        return new_row, vote_local
    return new_row


def update_sample(
    cfg: TMConfig,
    state: TMState,
    x: jax.Array,        # (o,)
    y: jax.Array,        # () int
    rng: jax.Array,
    *,
    pol: jax.Array | None = None,
    axis_name: str | tuple[str, ...] | None = None,
    clause_start: jax.Array | None = None,
    clause_mask: jax.Array | None = None,
    stale_votes: jax.Array | None = None,
) -> TMState:
    """One online update (the paper's per-sample learning).

    Target class receives a positive round; one uniformly drawn *other*
    class receives a negative round (standard multiclass TM scheme).

    When ``state`` holds only a clause shard, pass the shard's polarity
    slice ``pol``, the mesh clause ``axis_name`` (vote psum) and the shard's
    global ``clause_start`` (rand slicing) — every shard draws the identical
    full-size randomness and consumes its own rows, so the sharded update is
    bit-exact with the single-device one. ``clause_mask`` (n,) freezes
    padding rows of a ragged slice (see ``_class_round``).

    ``stale_votes`` (m,) switches both rounds to asynchronous stale-vote
    feedback (DESIGN.md §11): no vote psum — each round reads its class's
    stale remote term instead — and the update returns
    ``(state, (votes, counts))`` where ``votes``/``counts`` (m,) int32
    scatter the rounds' *local* partial vote sums by class (the
    ``VoteAccumulator`` write-buffer contribution). ``axis_name`` is
    ignored for the vote in this mode.
    """
    lit = literals_from_input(x)
    k_neg, k_a, k_b = jax.random.split(rng, 3)
    # sample negative class ≠ y
    neg = jax.random.randint(k_neg, (), 0, cfg.n_classes - 1)
    neg = jnp.where(neg >= y, neg + 1, neg)

    ta = state.ta_state
    rands_a = draw_feedback_rands(cfg, k_a)
    rands_b = draw_feedback_rands(cfg, k_b)
    if clause_start is not None:
        n_local = ta.shape[1]
        rands_a = _slice_rands(rands_a, clause_start, n_local)
        rands_b = _slice_rands(rands_b, clause_start, n_local)
    if stale_votes is not None:
        row_pos, v_pos = _class_round(
            cfg, ta[y], lit, rands_a, jnp.asarray(True), pol=pol,
            clause_mask=clause_mask, stale_vote=stale_votes[y])
        ta = ta.at[y].set(row_pos)
        row_neg, v_neg = _class_round(
            cfg, ta[neg], lit, rands_b, jnp.asarray(False), pol=pol,
            clause_mask=clause_mask, stale_vote=stale_votes[neg])
        ta = ta.at[neg].set(row_neg)
        m = stale_votes.shape[0]
        votes = jnp.zeros((m,), jnp.int32).at[y].set(v_pos).at[neg].set(v_neg)
        counts = jnp.zeros((m,), jnp.int32).at[y].set(1).at[neg].set(1)
        return TMState(ta_state=ta), (votes, counts)
    row_pos = _class_round(cfg, ta[y], lit, rands_a, jnp.asarray(True),
                           pol=pol, axis_name=axis_name,
                           clause_mask=clause_mask)
    ta = ta.at[y].set(row_pos)
    row_neg = _class_round(cfg, ta[neg], lit, rands_b, jnp.asarray(False),
                           pol=pol, axis_name=axis_name,
                           clause_mask=clause_mask)
    ta = ta.at[neg].set(row_neg)
    return TMState(ta_state=ta)


def update_batch_sequential(
    cfg: TMConfig, state: TMState, xs: jax.Array, ys: jax.Array,
    rng: jax.Array, *,
    pol: jax.Array | None = None,
    axis_name: str | tuple[str, ...] | None = None,
    clause_start: jax.Array | None = None,
    mask: jax.Array | None = None,
    clause_mask: jax.Array | None = None,
    stale_votes: jax.Array | None = None,
) -> TMState:
    """Faithful online learning over a batch: lax.scan of per-sample updates.

    Sharded mode (kwargs set): the *full* batch is scanned on every clause
    shard — online learning is sequential in samples by definition — with one
    vote psum per class round as the only collective.

    ``mask`` (B,) bool marks valid samples: masked-out rows consume their
    randomness (so padded and unpadded streams stay key-aligned) but apply no
    state update — the padding contract for fixed-shape trailing batches.
    ``clause_mask`` (n,) bool marks valid *clause rows*: the transpose
    contract for ragged shard slices (padding rows frozen, DESIGN.md §9).

    ``stale_votes`` (m,) switches every round to asynchronous stale-vote
    feedback (zero collectives in the scan, DESIGN.md §11) and the return
    value to ``(state, (votes_sum, counts))`` — the per-class sum and count
    of local partial votes observed over the batch's rounds (masked rows
    excluded), from which the caller derives the accumulator's new write
    buffer. The stale term is constant across the batch: it refreshes at
    the K-step boundary, never mid-scan.
    """
    keys = jax.random.split(rng, xs.shape[0])
    valid = jnp.ones(xs.shape[0], bool) if mask is None else mask

    if stale_votes is not None:
        def body_async(carry, inp):
            st, vs, vc = carry
            x, y, k, m = inp
            new, (dv, dc) = update_sample(
                cfg, st, x, y, k, pol=pol, clause_start=clause_start,
                clause_mask=clause_mask, stale_votes=stale_votes)
            st = TMState(ta_state=jnp.where(m, new.ta_state, st.ta_state))
            return (st, vs + jnp.where(m, dv, 0), vc + jnp.where(m, dc, 0)), None

        zeros = jnp.zeros(stale_votes.shape, jnp.int32)
        (out, vs, vc), _ = jax.lax.scan(
            body_async, (state, zeros, zeros), (xs, ys, keys, valid))
        return out, (vs, vc)

    def body(st, inp):
        x, y, k, m = inp
        new = update_sample(cfg, st, x, y, k, pol=pol, axis_name=axis_name,
                            clause_start=clause_start,
                            clause_mask=clause_mask)
        return TMState(ta_state=jnp.where(m, new.ta_state, st.ta_state)), None

    out, _ = jax.lax.scan(body, state, (xs, ys, keys, valid))
    return out


def update_batch_parallel(
    cfg: TMConfig, state: TMState, xs: jax.Array, ys: jax.Array,
    rng: jax.Array, *,
    pol: jax.Array | None = None,
    axis_name: str | tuple[str, ...] | None = None,
    clause_start: jax.Array | None = None,
    batch_axes: tuple[str, ...] = (),
    batch_start: jax.Array | None = None,
    batch_total: int | None = None,
    mask: jax.Array | None = None,
    clause_mask: jax.Array | None = None,
    stale_votes: jax.Array | None = None,
) -> TMState:
    """Beyond-paper: batch-parallel update (deltas computed vs the *same*
    pre-batch state, then summed). An approximation of online learning —
    documented in DESIGN.md; used for throughput-oriented training.

    Sharded mode additionally shards the *batch*: ``xs`` holds this data
    shard's slice of a ``batch_total``-sized global batch starting at
    ``batch_start``; per-sample keys are the global split sliced to match
    (bit-exact with the single-device split), and the summed deltas are
    psum'd over ``batch_axes`` before the clip. ``mask`` (B,) bool zeroes
    the deltas of padded samples (randomness still consumed per row);
    ``clause_mask`` (n,) bool zeroes the deltas of padded clause rows
    (ragged shard slices, DESIGN.md §9).

    ``stale_votes`` (m,) switches the per-sample rounds to asynchronous
    stale-vote feedback (no per-round vote psum, DESIGN.md §11) and the
    return value to ``(state, (votes_sum, counts))`` — local partial-vote
    statistics summed over this rank's valid samples, *not* reduced over
    ``batch_axes`` (each vote rank keeps its own accumulator row). The
    delta psum over ``batch_axes`` is unchanged: state composition stays
    exact; only the vote feedback term is stale.
    """
    if batch_total is None:
        keys = jax.random.split(rng, xs.shape[0])
    else:
        # global key stream, local slice — identical keys per global sample
        kd = jax.random.key_data(jax.random.split(rng, batch_total))
        kd = jax.lax.dynamic_slice_in_dim(kd, batch_start, xs.shape[0], 0)
        keys = jax.random.wrap_key_data(kd)

    if stale_votes is not None:
        def one_async(x, y, k):
            new, (dv, dc) = update_sample(
                cfg, state, x, y, k, pol=pol, clause_start=clause_start,
                clause_mask=clause_mask, stale_votes=stale_votes)
            delta = (new.ta_state.astype(jnp.int32)
                     - state.ta_state.astype(jnp.int32))
            return delta, dv, dc

        deltas, dvs, dcs = jax.vmap(one_async)(xs, ys, keys)
        if mask is not None:
            deltas = jnp.where(mask[:, None, None, None], deltas, 0)
            dvs = jnp.where(mask[:, None], dvs, 0)
            dcs = jnp.where(mask[:, None], dcs, 0)
        deltas = deltas.sum(axis=0)
        if batch_axes:
            deltas = jax.lax.psum(deltas, batch_axes)
        ta = jnp.clip(
            state.ta_state.astype(jnp.int32) + deltas, 1, 2 * cfg.n_states
        ).astype(cfg.state_dtype)
        return TMState(ta_state=ta), (dvs.sum(axis=0), dcs.sum(axis=0))

    def one(x, y, k):
        new = update_sample(cfg, state, x, y, k, pol=pol, axis_name=axis_name,
                            clause_start=clause_start,
                            clause_mask=clause_mask)
        return (new.ta_state.astype(jnp.int32) - state.ta_state.astype(jnp.int32))

    deltas = jax.vmap(one)(xs, ys, keys)
    if mask is not None:
        deltas = jnp.where(mask[:, None, None, None], deltas, 0)
    deltas = deltas.sum(axis=0)
    if batch_axes:
        deltas = jax.lax.psum(deltas, batch_axes)
    ta = jnp.clip(
        state.ta_state.astype(jnp.int32) + deltas, 1, 2 * cfg.n_states
    ).astype(cfg.state_dtype)
    return TMState(ta_state=ta)


def accuracy(cfg: TMConfig, state: TMState, xs: jax.Array, ys: jax.Array) -> jax.Array:
    """Fraction of ``xs`` rows whose argmax vote equals ``ys``."""
    return jnp.mean((predict(cfg, state, xs) == ys).astype(jnp.float32))
