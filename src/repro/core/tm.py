"""Tsetlin Machine forward pass and learning (paper §2).

Evaluation paths (all semantically identical; cross-validated in tests):
  * ``dense_clause_outputs``   — exhaustive evaluation, the paper's baseline.
  * ``bitpacked`` (kernels/)   — dense over 32x packed words (VPU-friendly).
  * ``compact_eval`` (indexing.py) — gather over included literals only;
    work ∝ Σ clause lengths (the paper's sparsity).
  * ``indexed_scores`` (indexing.py) — the paper's falsification index.

Learning implements Type I / Type II feedback with explicit uniform draws
passed in, so the pure-numpy oracle in ``core/ref.py`` can be driven with the
*same* randomness and compared bit-exactly.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.types import (
    TMConfig,
    TMState,
    clause_polarity,
    include_mask,
    literals_from_input,
)

# ---------------------------------------------------------------------------
# Forward pass
# ---------------------------------------------------------------------------


def dense_clause_outputs(
    cfg: TMConfig, state: TMState, x: jax.Array, *, empty_output: int | None = None
) -> jax.Array:
    """Exhaustive clause evaluation. x: (B, o) {0,1} → (B, m, n) uint8.

    A clause is true iff no included literal is false:
      falsified(b, i, j) = ∃k: include[i,j,k] ∧ ¬literal[b,k].
    Implemented as an integer matmul (false-literal count per clause) so the
    dense baseline is itself vectorised — the paper's C baseline is a tight
    loop; an un-vectorised JAX loop would strawman it.
    """
    lit = literals_from_input(x)                      # (B, 2o)
    inc = include_mask(cfg, state)                    # (m, n, 2o)
    false_lit = (1 - lit).astype(jnp.float32)         # (B, 2o)
    # count of included-and-false literals per clause
    counts = jnp.einsum("bk,mnk->bmn", false_lit, inc.astype(jnp.float32))
    out = (counts < 0.5).astype(jnp.uint8)            # (B, m, n)
    empty_output = cfg.empty_clause_output if empty_output is None else empty_output
    if empty_output == 0:
        empty = ~jnp.any(inc, axis=-1)                # (m, n)
        out = out * (1 - empty.astype(jnp.uint8))[None]
    return out


def clause_votes(cfg: TMConfig, clause_out: jax.Array) -> jax.Array:
    """(B, m, n) clause outputs → (B, m) polarity-signed vote sums (Eq. 3)."""
    pol = clause_polarity(cfg)                        # (n,)
    return jnp.einsum("bmn,n->bm", clause_out.astype(jnp.int32), pol)


def scores(cfg: TMConfig, state: TMState, x: jax.Array) -> jax.Array:
    """(B, m) class scores via the dense path."""
    return clause_votes(cfg, dense_clause_outputs(cfg, state, x))


def predict(cfg: TMConfig, state: TMState, x: jax.Array) -> jax.Array:
    """(B,) argmax class (Eq. 3)."""
    return jnp.argmax(scores(cfg, state, x), axis=-1)


def packed_clause_outputs(include_packed: jax.Array, x: jax.Array) -> jax.Array:
    """(m, n, W) packed includes + (B, o) inputs → (B, m, n) bool outputs.

    Pure-XLA packed eval body, shared by the XLA score paths and the packed
    engines' shard-local ``partial_scores`` (Eq. 4 semantics: a clause is
    true iff no included literal is violated).
    """
    from repro.core.bitpack import packed_literals

    lit = packed_literals(x)                                     # (B,W)
    viol = include_packed[None] & (~lit)[:, None, None]          # (B,m,n,W)
    return ~jnp.any(viol != 0, axis=-1)                          # (B,m,n)


def bitpacked_scores_packed(
    cfg: TMConfig, include_packed: jax.Array, x: jax.Array
) -> jax.Array:
    """XLA bit-packed eval from a *prepared* packed-include cache.

    ``include_packed``: (m, n, W) uint32 — e.g. the ``bitpack`` engine cache
    kept in sync event-wise by the registry (core/engines.py), so inference
    never repacks the full include mask.
    """
    out = packed_clause_outputs(include_packed, x)
    return clause_votes(cfg, out.astype(jnp.uint8))


def bitpacked_scores(cfg: TMConfig, state: TMState, x: jax.Array) -> jax.Array:
    """Dense eval over 32×-packed words, pure XLA (no Pallas).

    Same algorithm as kernels/clause_eval.py — on CPU this is the
    executable fast path (interpret-mode Pallas runs the kernel body in
    Python); on TPU the Pallas kernel owns the fused-vote variant.
    Memory traffic vs the f32-matmul dense baseline drops ~128×
    (uint32 words vs f32 per literal).
    """
    from repro.core.bitpack import pack_bits

    inc = pack_bits(include_mask(cfg, state).astype(jnp.uint8))  # (m,n,W)
    return bitpacked_scores_packed(cfg, inc, x)


# ---------------------------------------------------------------------------
# Learning: Type I / Type II feedback (paper §2, Granmo 2018 semantics)
# ---------------------------------------------------------------------------


class FeedbackRands(NamedTuple):
    """Uniform draws consumed by one class-round of feedback.

    Passing these explicitly makes the update a deterministic function, so
    the numpy oracle can replay identical randomness.
    """

    clause_gate: jax.Array  # (n,)      uniforms vs update probability p
    type_i: jax.Array       # (n, 2o)   uniforms vs 1/s and (s-1)/s


def draw_feedback_rands(cfg: TMConfig, rng: jax.Array) -> FeedbackRands:
    k1, k2 = jax.random.split(rng)
    return FeedbackRands(
        clause_gate=jax.random.uniform(k1, (cfg.n_clauses,)),
        type_i=jax.random.uniform(k2, (cfg.n_clauses, cfg.n_literals)),
    )


def _slice_rands(rands: FeedbackRands, start: jax.Array,
                 n_local: int) -> FeedbackRands:
    """Clause-shard slice of a *full* draw (clause-sharded learning).

    Every shard materialises the identical full-size draw and takes its own
    row block — the only scheme that keeps sharded learning bit-exact with
    the single-device path (per-shard draws would consume different keys).
    """
    return FeedbackRands(
        clause_gate=jax.lax.dynamic_slice_in_dim(
            rands.clause_gate, start, n_local, 0),
        type_i=jax.lax.dynamic_slice_in_dim(rands.type_i, start, n_local, 0),
    )


def _type_i_delta(
    cfg: TMConfig,
    clause_out: jax.Array,  # (n,) uint8 — evaluated with empty_output=1
    lit: jax.Array,         # (2o,) uint8
    include: jax.Array,     # (n, 2o) bool
    u: jax.Array,           # (n, 2o) uniforms
) -> jax.Array:
    """Type I feedback state deltas (n, 2o) int16 — combats false negatives.

    clause==1, lit==1 : +1 w.p. (s-1)/s   (or w.p. 1 if boost_true_positive)
    clause==1, lit==0 : -1 w.p. 1/s
    clause==0         : -1 w.p. 1/s   (all literals)
    """
    del include  # Type I acts on states regardless of current action
    inv_s = 1.0 / cfg.s
    c1 = (clause_out == 1)[:, None]                   # (n, 1)
    l1 = (lit == 1)[None, :]                          # (1, 2o)
    p_reward = 1.0 if cfg.boost_true_positive else (1.0 - inv_s)
    reward = c1 & l1 & (u < p_reward)
    penalty = ((c1 & ~l1) | ~c1) & (u < inv_s)
    return reward.astype(jnp.int16) - penalty.astype(jnp.int16)


def _type_ii_delta(
    cfg: TMConfig,
    clause_out: jax.Array,  # (n,)
    lit: jax.Array,         # (2o,)
    include: jax.Array,     # (n, 2o)
) -> jax.Array:
    """Type II feedback deltas (n, 2o) int16 — combats false positives.

    clause==1, lit==0, action==exclude : +1 (deterministic)
    """
    c1 = (clause_out == 1)[:, None]
    l0 = (lit == 0)[None, :]
    return (c1 & l0 & ~include).astype(jnp.int16)


def _class_round(
    cfg: TMConfig,
    ta_row: jax.Array,       # (n, 2o) — states of one class (or a clause shard)
    lit: jax.Array,          # (2o,)
    rands: FeedbackRands,
    positive_round: jax.Array,  # scalar bool — True: target-class round
    *,
    pol: jax.Array | None = None,   # (n,) ±1 — pass the local slice when sharded
    # mesh axes the votes psum over: the clause axis, or (batch axes + clause
    # axis) when the sequential path additionally splits clauses over the
    # data axes (hierarchical data×clause sharding)
    axis_name: str | tuple[str, ...] | None = None,
) -> jax.Array:
    """One feedback round for one class; returns updated (n, 2o) states.

    Clause-sharded learning (core/distributed.py) calls this with the local
    ``ta_row``/``rands``/``pol`` slices and the mesh clause ``axis_name``: the
    per-class vote is the *only* cross-shard quantity (one psum — the vote
    all-reduce of the Massively Parallel TM architecture); Type I/II feedback
    is clause-local given that vote.
    """
    include = ta_row > cfg.n_states
    false_cnt = jnp.einsum(
        "k,nk->n", (1 - lit).astype(jnp.float32), include.astype(jnp.float32)
    )
    clause_out = (false_cnt < 0.5).astype(jnp.uint8)  # empty clause ⇒ 1 (learning)
    if pol is None:
        pol = clause_polarity(cfg)
    t = float(cfg.threshold)
    vote_sum = jnp.sum(clause_out.astype(jnp.int32) * pol)
    if axis_name is not None:
        vote_sum = jax.lax.psum(vote_sum, axis_name)
    votes = jnp.clip(vote_sum, -t, t)
    p = jnp.where(positive_round, (t - votes) / (2 * t), (t + votes) / (2 * t))
    active = rands.clause_gate < p                    # (n,)

    pos_pol = pol > 0
    # target round: positive clauses→Type I, negative→Type II; swapped otherwise
    gets_type_i = jnp.where(positive_round, pos_pol, ~pos_pol)

    d1 = _type_i_delta(cfg, clause_out, lit, include, rands.type_i)
    d2 = _type_ii_delta(cfg, clause_out, lit, include)
    delta = jnp.where(
        (active & gets_type_i)[:, None], d1,
        jnp.where((active & ~gets_type_i)[:, None], d2, 0),
    ).astype(jnp.int16)
    return jnp.clip(ta_row + delta, 1, 2 * cfg.n_states).astype(cfg.state_dtype)


def update_sample(
    cfg: TMConfig,
    state: TMState,
    x: jax.Array,        # (o,)
    y: jax.Array,        # () int
    rng: jax.Array,
    *,
    pol: jax.Array | None = None,
    axis_name: str | tuple[str, ...] | None = None,
    clause_start: jax.Array | None = None,
) -> TMState:
    """One online update (the paper's per-sample learning).

    Target class receives a positive round; one uniformly drawn *other*
    class receives a negative round (standard multiclass TM scheme).

    When ``state`` holds only a clause shard, pass the shard's polarity
    slice ``pol``, the mesh clause ``axis_name`` (vote psum) and the shard's
    global ``clause_start`` (rand slicing) — every shard draws the identical
    full-size randomness and consumes its own rows, so the sharded update is
    bit-exact with the single-device one.
    """
    lit = literals_from_input(x)
    k_neg, k_a, k_b = jax.random.split(rng, 3)
    # sample negative class ≠ y
    neg = jax.random.randint(k_neg, (), 0, cfg.n_classes - 1)
    neg = jnp.where(neg >= y, neg + 1, neg)

    ta = state.ta_state
    rands_a = draw_feedback_rands(cfg, k_a)
    rands_b = draw_feedback_rands(cfg, k_b)
    if clause_start is not None:
        n_local = ta.shape[1]
        rands_a = _slice_rands(rands_a, clause_start, n_local)
        rands_b = _slice_rands(rands_b, clause_start, n_local)
    row_pos = _class_round(cfg, ta[y], lit, rands_a, jnp.asarray(True),
                           pol=pol, axis_name=axis_name)
    ta = ta.at[y].set(row_pos)
    row_neg = _class_round(cfg, ta[neg], lit, rands_b, jnp.asarray(False),
                           pol=pol, axis_name=axis_name)
    ta = ta.at[neg].set(row_neg)
    return TMState(ta_state=ta)


def update_batch_sequential(
    cfg: TMConfig, state: TMState, xs: jax.Array, ys: jax.Array,
    rng: jax.Array, *,
    pol: jax.Array | None = None,
    axis_name: str | tuple[str, ...] | None = None,
    clause_start: jax.Array | None = None,
    mask: jax.Array | None = None,
) -> TMState:
    """Faithful online learning over a batch: lax.scan of per-sample updates.

    Sharded mode (kwargs set): the *full* batch is scanned on every clause
    shard — online learning is sequential in samples by definition — with one
    vote psum per class round as the only collective.

    ``mask`` (B,) bool marks valid samples: masked-out rows consume their
    randomness (so padded and unpadded streams stay key-aligned) but apply no
    state update — the padding contract for fixed-shape trailing batches.
    """
    keys = jax.random.split(rng, xs.shape[0])

    def body(st, inp):
        x, y, k, m = inp
        new = update_sample(cfg, st, x, y, k, pol=pol, axis_name=axis_name,
                            clause_start=clause_start)
        return TMState(ta_state=jnp.where(m, new.ta_state, st.ta_state)), None

    valid = jnp.ones(xs.shape[0], bool) if mask is None else mask
    out, _ = jax.lax.scan(body, state, (xs, ys, keys, valid))
    return out


def update_batch_parallel(
    cfg: TMConfig, state: TMState, xs: jax.Array, ys: jax.Array,
    rng: jax.Array, *,
    pol: jax.Array | None = None,
    axis_name: str | tuple[str, ...] | None = None,
    clause_start: jax.Array | None = None,
    batch_axes: tuple[str, ...] = (),
    batch_start: jax.Array | None = None,
    batch_total: int | None = None,
    mask: jax.Array | None = None,
) -> TMState:
    """Beyond-paper: batch-parallel update (deltas computed vs the *same*
    pre-batch state, then summed). An approximation of online learning —
    documented in DESIGN.md; used for throughput-oriented training.

    Sharded mode additionally shards the *batch*: ``xs`` holds this data
    shard's slice of a ``batch_total``-sized global batch starting at
    ``batch_start``; per-sample keys are the global split sliced to match
    (bit-exact with the single-device split), and the summed deltas are
    psum'd over ``batch_axes`` before the clip. ``mask`` (B,) bool zeroes
    the deltas of padded samples (randomness still consumed per row).
    """
    if batch_total is None:
        keys = jax.random.split(rng, xs.shape[0])
    else:
        # global key stream, local slice — identical keys per global sample
        kd = jax.random.key_data(jax.random.split(rng, batch_total))
        kd = jax.lax.dynamic_slice_in_dim(kd, batch_start, xs.shape[0], 0)
        keys = jax.random.wrap_key_data(kd)

    def one(x, y, k):
        new = update_sample(cfg, state, x, y, k, pol=pol, axis_name=axis_name,
                            clause_start=clause_start)
        return (new.ta_state.astype(jnp.int32) - state.ta_state.astype(jnp.int32))

    deltas = jax.vmap(one)(xs, ys, keys)
    if mask is not None:
        deltas = jnp.where(mask[:, None, None, None], deltas, 0)
    deltas = deltas.sum(axis=0)
    if batch_axes:
        deltas = jax.lax.psum(deltas, batch_axes)
    ta = jnp.clip(
        state.ta_state.astype(jnp.int32) + deltas, 1, 2 * cfg.n_states
    ).astype(cfg.state_dtype)
    return TMState(ta_state=ta)


def accuracy(cfg: TMConfig, state: TMState, xs: jax.Array, ys: jax.Array) -> jax.Array:
    return jnp.mean((predict(cfg, state, xs) == ys).astype(jnp.float32))
