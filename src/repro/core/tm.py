"""Tsetlin Machine forward pass and learning (paper §2).

Evaluation paths (all semantically identical; cross-validated in tests):
  * ``dense_clause_outputs``   — exhaustive evaluation, the paper's baseline.
  * ``bitpacked`` (kernels/)   — dense over 32x packed words (VPU-friendly).
  * ``compact_eval`` (indexing.py) — gather over included literals only;
    work ∝ Σ clause lengths (the paper's sparsity).
  * ``indexed_scores`` (indexing.py) — the paper's falsification index.

Learning implements Type I / Type II feedback with explicit uniform draws
passed in, so the pure-numpy oracle in ``core/ref.py`` can be driven with the
*same* randomness and compared bit-exactly.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.types import (
    TMConfig,
    TMState,
    clause_polarity,
    include_mask,
    literals_from_input,
)

# ---------------------------------------------------------------------------
# Forward pass
# ---------------------------------------------------------------------------


def dense_clause_outputs(
    cfg: TMConfig, state: TMState, x: jax.Array, *, empty_output: int | None = None
) -> jax.Array:
    """Exhaustive clause evaluation. x: (B, o) {0,1} → (B, m, n) uint8.

    A clause is true iff no included literal is false:
      falsified(b, i, j) = ∃k: include[i,j,k] ∧ ¬literal[b,k].
    Implemented as an integer matmul (false-literal count per clause) so the
    dense baseline is itself vectorised — the paper's C baseline is a tight
    loop; an un-vectorised JAX loop would strawman it.
    """
    lit = literals_from_input(x)                      # (B, 2o)
    inc = include_mask(cfg, state)                    # (m, n, 2o)
    false_lit = (1 - lit).astype(jnp.float32)         # (B, 2o)
    # count of included-and-false literals per clause
    counts = jnp.einsum("bk,mnk->bmn", false_lit, inc.astype(jnp.float32))
    out = (counts < 0.5).astype(jnp.uint8)            # (B, m, n)
    empty_output = cfg.empty_clause_output if empty_output is None else empty_output
    if empty_output == 0:
        empty = ~jnp.any(inc, axis=-1)                # (m, n)
        out = out * (1 - empty.astype(jnp.uint8))[None]
    return out


def clause_votes(cfg: TMConfig, clause_out: jax.Array) -> jax.Array:
    """(B, m, n) clause outputs → (B, m) polarity-signed vote sums (Eq. 3)."""
    pol = clause_polarity(cfg)                        # (n,)
    return jnp.einsum("bmn,n->bm", clause_out.astype(jnp.int32), pol)


def scores(cfg: TMConfig, state: TMState, x: jax.Array) -> jax.Array:
    """(B, m) class scores via the dense path."""
    return clause_votes(cfg, dense_clause_outputs(cfg, state, x))


def predict(cfg: TMConfig, state: TMState, x: jax.Array) -> jax.Array:
    """(B,) argmax class (Eq. 3)."""
    return jnp.argmax(scores(cfg, state, x), axis=-1)


def bitpacked_scores_packed(
    cfg: TMConfig, include_packed: jax.Array, x: jax.Array
) -> jax.Array:
    """XLA bit-packed eval from a *prepared* packed-include cache.

    ``include_packed``: (m, n, W) uint32 — e.g. the ``bitpack`` engine cache
    kept in sync event-wise by the registry (core/engines.py), so inference
    never repacks the full include mask.
    """
    from repro.core.bitpack import packed_literals

    lit = packed_literals(x)                                     # (B,W)
    viol = include_packed[None] & (~lit)[:, None, None]          # (B,m,n,W)
    out = ~jnp.any(viol != 0, axis=-1)                           # (B,m,n)
    return clause_votes(cfg, out.astype(jnp.uint8))


def bitpacked_scores(cfg: TMConfig, state: TMState, x: jax.Array) -> jax.Array:
    """Dense eval over 32×-packed words, pure XLA (no Pallas).

    Same algorithm as kernels/clause_eval.py — on CPU this is the
    executable fast path (interpret-mode Pallas runs the kernel body in
    Python); on TPU the Pallas kernel owns the fused-vote variant.
    Memory traffic vs the f32-matmul dense baseline drops ~128×
    (uint32 words vs f32 per literal).
    """
    from repro.core.bitpack import pack_bits

    inc = pack_bits(include_mask(cfg, state).astype(jnp.uint8))  # (m,n,W)
    return bitpacked_scores_packed(cfg, inc, x)


# ---------------------------------------------------------------------------
# Learning: Type I / Type II feedback (paper §2, Granmo 2018 semantics)
# ---------------------------------------------------------------------------


class FeedbackRands(NamedTuple):
    """Uniform draws consumed by one class-round of feedback.

    Passing these explicitly makes the update a deterministic function, so
    the numpy oracle can replay identical randomness.
    """

    clause_gate: jax.Array  # (n,)      uniforms vs update probability p
    type_i: jax.Array       # (n, 2o)   uniforms vs 1/s and (s-1)/s


def draw_feedback_rands(cfg: TMConfig, rng: jax.Array) -> FeedbackRands:
    k1, k2 = jax.random.split(rng)
    return FeedbackRands(
        clause_gate=jax.random.uniform(k1, (cfg.n_clauses,)),
        type_i=jax.random.uniform(k2, (cfg.n_clauses, cfg.n_literals)),
    )


def _type_i_delta(
    cfg: TMConfig,
    clause_out: jax.Array,  # (n,) uint8 — evaluated with empty_output=1
    lit: jax.Array,         # (2o,) uint8
    include: jax.Array,     # (n, 2o) bool
    u: jax.Array,           # (n, 2o) uniforms
) -> jax.Array:
    """Type I feedback state deltas (n, 2o) int16 — combats false negatives.

    clause==1, lit==1 : +1 w.p. (s-1)/s   (or w.p. 1 if boost_true_positive)
    clause==1, lit==0 : -1 w.p. 1/s
    clause==0         : -1 w.p. 1/s   (all literals)
    """
    del include  # Type I acts on states regardless of current action
    inv_s = 1.0 / cfg.s
    c1 = (clause_out == 1)[:, None]                   # (n, 1)
    l1 = (lit == 1)[None, :]                          # (1, 2o)
    p_reward = 1.0 if cfg.boost_true_positive else (1.0 - inv_s)
    reward = c1 & l1 & (u < p_reward)
    penalty = ((c1 & ~l1) | ~c1) & (u < inv_s)
    return reward.astype(jnp.int16) - penalty.astype(jnp.int16)


def _type_ii_delta(
    cfg: TMConfig,
    clause_out: jax.Array,  # (n,)
    lit: jax.Array,         # (2o,)
    include: jax.Array,     # (n, 2o)
) -> jax.Array:
    """Type II feedback deltas (n, 2o) int16 — combats false positives.

    clause==1, lit==0, action==exclude : +1 (deterministic)
    """
    c1 = (clause_out == 1)[:, None]
    l0 = (lit == 0)[None, :]
    return (c1 & l0 & ~include).astype(jnp.int16)


def _class_round(
    cfg: TMConfig,
    ta_row: jax.Array,       # (n, 2o) — states of one class
    lit: jax.Array,          # (2o,)
    rands: FeedbackRands,
    positive_round: jax.Array,  # scalar bool — True: target-class round
) -> jax.Array:
    """One feedback round for one class; returns updated (n, 2o) states."""
    include = ta_row > cfg.n_states
    false_cnt = jnp.einsum(
        "k,nk->n", (1 - lit).astype(jnp.float32), include.astype(jnp.float32)
    )
    clause_out = (false_cnt < 0.5).astype(jnp.uint8)  # empty clause ⇒ 1 (learning)
    t = float(cfg.threshold)
    votes = jnp.clip(
        jnp.sum(clause_out.astype(jnp.int32) * clause_polarity(cfg)), -t, t
    )
    p = jnp.where(positive_round, (t - votes) / (2 * t), (t + votes) / (2 * t))
    active = rands.clause_gate < p                    # (n,)

    pos_pol = jnp.arange(cfg.n_clauses) < cfg.half_clauses
    # target round: positive clauses→Type I, negative→Type II; swapped otherwise
    gets_type_i = jnp.where(positive_round, pos_pol, ~pos_pol)

    d1 = _type_i_delta(cfg, clause_out, lit, include, rands.type_i)
    d2 = _type_ii_delta(cfg, clause_out, lit, include)
    delta = jnp.where(
        (active & gets_type_i)[:, None], d1,
        jnp.where((active & ~gets_type_i)[:, None], d2, 0),
    ).astype(jnp.int16)
    return jnp.clip(ta_row + delta, 1, 2 * cfg.n_states).astype(cfg.state_dtype)


def update_sample(
    cfg: TMConfig,
    state: TMState,
    x: jax.Array,        # (o,)
    y: jax.Array,        # () int
    rng: jax.Array,
) -> TMState:
    """One online update (the paper's per-sample learning).

    Target class receives a positive round; one uniformly drawn *other*
    class receives a negative round (standard multiclass TM scheme).
    """
    lit = literals_from_input(x)
    k_neg, k_a, k_b = jax.random.split(rng, 3)
    # sample negative class ≠ y
    neg = jax.random.randint(k_neg, (), 0, cfg.n_classes - 1)
    neg = jnp.where(neg >= y, neg + 1, neg)

    ta = state.ta_state
    row_pos = _class_round(cfg, ta[y], lit, draw_feedback_rands(cfg, k_a),
                           jnp.asarray(True))
    ta = ta.at[y].set(row_pos)
    row_neg = _class_round(cfg, ta[neg], lit, draw_feedback_rands(cfg, k_b),
                           jnp.asarray(False))
    ta = ta.at[neg].set(row_neg)
    return TMState(ta_state=ta)


def update_batch_sequential(
    cfg: TMConfig, state: TMState, xs: jax.Array, ys: jax.Array, rng: jax.Array
) -> TMState:
    """Faithful online learning over a batch: lax.scan of per-sample updates."""
    keys = jax.random.split(rng, xs.shape[0])

    def body(st, inp):
        x, y, k = inp
        return update_sample(cfg, st, x, y, k), None

    out, _ = jax.lax.scan(body, state, (xs, ys, keys))
    return out


def update_batch_parallel(
    cfg: TMConfig, state: TMState, xs: jax.Array, ys: jax.Array, rng: jax.Array
) -> TMState:
    """Beyond-paper: batch-parallel update (deltas computed vs the *same*
    pre-batch state, then summed). An approximation of online learning —
    documented in DESIGN.md; used for throughput-oriented training.
    """
    keys = jax.random.split(rng, xs.shape[0])

    def one(x, y, k):
        new = update_sample(cfg, state, x, y, k)
        return (new.ta_state.astype(jnp.int32) - state.ta_state.astype(jnp.int32))

    deltas = jax.vmap(one)(xs, ys, keys).sum(axis=0)
    ta = jnp.clip(
        state.ta_state.astype(jnp.int32) + deltas, 1, 2 * cfg.n_states
    ).astype(cfg.state_dtype)
    return TMState(ta_state=ta)


def accuracy(cfg: TMConfig, state: TMState, xs: jax.Array, ys: jax.Array) -> jax.Array:
    return jnp.mean((predict(cfg, state, xs) == ys).astype(jnp.float32))
