"""Paper core: Tsetlin Machine + clause indexing (Gorji et al. 2020)."""
from repro.core.types import (
    TMConfig,
    TMState,
    clause_polarity,
    include_mask,
    init_tm,
    literals_from_input,
)
from repro.core.tm import (
    accuracy,
    clause_votes,
    dense_clause_outputs,
    predict,
    scores,
    update_batch_parallel,
    update_batch_sequential,
    update_sample,
)
from repro.core.indexing import (
    ClauseIndex,
    CompactClauses,
    EventBuffer,
    apply_events,
    build_index,
    compact,
    compact_apply_events,
    compact_eval,
    compact_scores,
    delete,
    dense_work,
    empty_index,
    events_from_transition,
    index_update,
    indexed_scores,
    indexed_work,
    insert,
    validate,
    validate_compact,
)
from repro.core.engines import (
    EvalEngine,
    get_engine,
    register_engine,
    registered_engines,
)
from repro.core.api import (
    TMBundle,
    bundle_predict,
    bundle_scores,
    init_bundle,
    train_step,
    train_step_jit,
)
from repro.core.session import (
    TMSession,
    Topology,
    TsetlinMachine,
)

__all__ = [
    "TMConfig", "TMState", "clause_polarity", "include_mask", "init_tm",
    "literals_from_input", "accuracy", "clause_votes", "dense_clause_outputs",
    "predict", "scores", "update_batch_parallel", "update_batch_sequential",
    "update_sample", "ClauseIndex", "CompactClauses", "apply_events",
    "build_index", "compact", "compact_apply_events", "compact_eval",
    "compact_scores", "delete", "dense_work", "empty_index",
    "EventBuffer",
    "events_from_transition", "index_update", "indexed_scores",
    "indexed_work", "insert",
    "validate", "validate_compact", "EvalEngine", "get_engine", "register_engine",
    "registered_engines", "TMBundle", "TMSession", "Topology",
    "TsetlinMachine", "bundle_predict", "bundle_scores", "init_bundle",
    "train_step", "train_step_jit",
]
