"""Paper core: Tsetlin Machine + clause indexing (Gorji et al. 2020)."""
from repro.core.types import (
    TMConfig,
    TMState,
    clause_polarity,
    include_mask,
    init_tm,
    literals_from_input,
)
from repro.core.tm import (
    accuracy,
    clause_votes,
    dense_clause_outputs,
    predict,
    scores,
    update_batch_parallel,
    update_batch_sequential,
    update_sample,
)
from repro.core.indexing import (
    ClauseIndex,
    CompactClauses,
    apply_events,
    build_index,
    compact,
    compact_eval,
    compact_scores,
    delete,
    dense_work,
    empty_index,
    events_from_transition,
    indexed_scores,
    indexed_work,
    insert,
    validate,
)

__all__ = [
    "TMConfig", "TMState", "clause_polarity", "include_mask", "init_tm",
    "literals_from_input", "accuracy", "clause_votes", "dense_clause_outputs",
    "predict", "scores", "update_batch_parallel", "update_batch_sequential",
    "update_sample", "ClauseIndex", "CompactClauses", "apply_events",
    "build_index", "compact", "compact_eval", "compact_scores", "delete",
    "dense_work", "empty_index", "events_from_transition", "indexed_scores",
    "indexed_work", "insert", "validate",
]
