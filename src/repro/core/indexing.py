"""Clause indexing (paper §3) — the paper's contribution, TPU-native.

Three structures, all fixed-shape functional pytrees:

  * ``ClauseIndex`` — the paper's inclusion lists ``L[i,k]`` (capacity-bounded
    rows of clause ids) + counts ``n[i,k]`` + position matrix ``M[i,j,k]``.
    ``insert``/``delete`` are the paper's O(1) swap-with-last updates as O(1)
    functional scatters.
  * ``indexed_scores`` — the paper's inference (Eq. 4): a sample's false
    literals falsify exactly the clauses in their inclusion lists. The hot
    body is the *matmul form*: ``pos != NA`` is the membership/include mask
    (``validate`` pins the identity), so the falsified-union is one
    contraction of false-literal indicators against it — no list walk, no
    scatter (``kernels/indexed.py``; routed per ``TMConfig.backend`` through
    the ``indexed_votes`` registry primitive).
  * ``index_update`` — batched O(events) replay of a masked event buffer
    (the ``index_update`` primitive): net events per TA cell, group per
    inclusion list via segment-cumsum, one vectorised scatter per buffer.
    Order-equivalent to the sequential ``apply_events`` oracle (kept, and
    pinned equivalent by property tests) with exact overflow accounting.
  * ``compact`` / ``compact_eval`` — the transpose (clause → included-literal
    indices), the gather-friendly layout a TPU prefers; work ∝ n·ℓ_max
    instead of n·2o, exploiting the *same* sparsity as the paper's lists
    (Σ clause lengths == Σ list lengths).

Capacity is the analogue of MoE expert capacity: lists are padded to
``capacity`` entries; overflow is a config error surfaced by ``validate``.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.types import TMConfig, TMState, include_mask, literals_from_input

NA = jnp.int32(-1)


class ClauseIndex(NamedTuple):
    lists: jax.Array   # (m, 2o, cap) int32 clause ids; NA beyond counts
    counts: jax.Array  # (m, 2o) int32
    pos: jax.Array     # (m, n, 2o) int32 position of clause j in list k; NA if absent

    @property
    def capacity(self) -> int:
        return self.lists.shape[-1]


# ---------------------------------------------------------------------------
# Construction
# ---------------------------------------------------------------------------


def shard_capacity(capacity: int, n_shards: int) -> int:
    """Per-shard list capacity for a clause-sharded index: ⌈capacity/S⌉.

    Capacity rows split with the clauses they hold: the per-shard worst case
    is the shard's clause count, which is ⌈n_clauses/S⌉ under the ragged
    clause geometry (DESIGN.md §9) — and the default capacity *is*
    ``n_clauses``, so the ceiling keeps every shard's worst case covered for
    any shard count, divisible or not. The assembled global
    ``(m, 2o, S·⌈capacity/S⌉)`` lists tensor is opaque storage outside
    shard_map; shard-local lists hold *local* clause ids, which stay dense
    (``[0, n_local)``) under clause-axis padding because padding rows never
    include a literal and therefore never enter a list.
    """
    return -(-capacity // n_shards)


def empty_index(cfg: TMConfig, capacity: int) -> ClauseIndex:
    """All TAs exclude ⇒ all lists empty (paper: 'rather straightforward')."""
    m, n, L = cfg.n_classes, cfg.n_clauses, cfg.n_literals
    return ClauseIndex(
        lists=jnp.full((m, L, capacity), NA, jnp.int32),
        counts=jnp.zeros((m, L), jnp.int32),
        pos=jnp.full((m, n, L), NA, jnp.int32),
    )


def build_index(cfg: TMConfig, state: TMState, capacity: int) -> ClauseIndex:
    """Vectorised full (re)build from the include mask.

    Clause ids are placed in ascending order per list. Equivalent to
    replaying inserts in clause order (tests pin this equivalence).
    """
    inc = include_mask(cfg, state)                      # (m, n, 2o)
    inc_t = jnp.swapaxes(inc, 1, 2)                     # (m, 2o, n)
    counts = inc_t.sum(-1).astype(jnp.int32)            # (m, 2o)
    # slot of clause j within list (i,k): number of including clauses < j
    slot = jnp.cumsum(inc_t.astype(jnp.int32), axis=-1) - 1  # (m, 2o, n)
    slot = jnp.where(inc_t, slot, NA)
    m, L, n = inc_t.shape
    cap = capacity
    # scatter clause ids into lists
    lists = jnp.full((m, L, cap), NA, jnp.int32)
    clause_ids = jnp.broadcast_to(jnp.arange(n, dtype=jnp.int32), (m, L, n))
    safe_slot = jnp.where(slot >= 0, slot, cap)          # out-of-range drops
    lists = lists.at[
        jnp.arange(m)[:, None, None],
        jnp.arange(L)[None, :, None],
        safe_slot,
    ].set(jnp.where(inc_t, clause_ids, NA), mode="drop")
    pos = jnp.swapaxes(slot, 1, 2)                       # (m, n, 2o)
    return ClauseIndex(lists=lists, counts=counts, pos=pos)


def validate(cfg: TMConfig, state: TMState, index: ClauseIndex) -> dict:
    """Invariant checks (used by property tests): returns bool scalars."""
    inc = include_mask(cfg, state)
    rebuilt_counts = jnp.swapaxes(inc, 1, 2).sum(-1).astype(jnp.int32)
    counts_ok = jnp.all(index.counts == rebuilt_counts)
    overflow_ok = jnp.all(index.counts <= index.capacity)
    # membership: pos[i,j,k] != NA  ⇔  include[i,j,k]
    member_ok = jnp.all((index.pos != NA) == inc)
    # round-trip: lists[i, k, pos[i,j,k]] == j wherever included
    m, n, L = index.pos.shape
    ii = jnp.arange(m)[:, None, None]
    kk = jnp.arange(L)[None, None, :]
    safe_pos = jnp.where(index.pos != NA, index.pos, 0)
    back = index.lists[ii, kk, safe_pos]                 # (m, n, 2o)
    jj = jnp.arange(n, dtype=jnp.int32)[None, :, None]
    roundtrip_ok = jnp.all(jnp.where(index.pos != NA, back == jj, True))
    return dict(
        counts_ok=counts_ok,
        overflow_ok=overflow_ok,
        member_ok=member_ok,
        roundtrip_ok=roundtrip_ok,
    )


# ---------------------------------------------------------------------------
# O(1) maintenance (paper §3 "Index Construction and Maintenance")
# ---------------------------------------------------------------------------


def insert(index: ClauseIndex, i: jax.Array, j: jax.Array, k: jax.Array) -> ClauseIndex:
    """TA (i, j, k) flipped exclude→include: append j to list (i, k).

        n_k^i       ← n_k^i + 1
        L_k^i[n]    ← j
        M_k^{ij}    ← n
    (0-based here; the paper writes 1-based.) O(1) scatters.
    """
    c = index.counts[i, k]
    lists = index.lists.at[i, k, c].set(j.astype(jnp.int32), mode="drop")
    pos = index.pos.at[i, j, k].set(c)
    counts = index.counts.at[i, k].add(1)
    return ClauseIndex(lists=lists, counts=counts, pos=pos)


def delete(index: ClauseIndex, i: jax.Array, j: jax.Array, k: jax.Array) -> ClauseIndex:
    """TA (i, j, k) flipped include→exclude: swap-with-last removal.

        p                 ← M_k^{ij}
        L_k^i[p]          ← L_k^i[n-1]      (overwrite with last)
        M_k^{i, moved}    ← p
        n_k^i             ← n_k^i - 1
        M_k^{ij}          ← NA
    O(1) scatters; bit-for-bit the paper's pointer algebra.
    """
    p = index.pos[i, j, k]
    last = index.counts[i, k] - 1
    moved = index.lists[i, k, last]
    lists = index.lists.at[i, k, p].set(moved)
    pos = index.pos.at[i, moved, k].set(p)
    lists = lists.at[i, k, last].set(NA)
    counts = index.counts.at[i, k].add(-1)
    pos = pos.at[i, j, k].set(NA)
    return ClauseIndex(lists=lists, counts=counts, pos=pos)


class Event(NamedTuple):
    """A TA include/exclude boundary crossing."""

    cls: jax.Array     # ()
    clause: jax.Array  # ()
    literal: jax.Array # ()
    is_insert: jax.Array  # () bool
    valid: jax.Array   # () bool — masking for fixed-shape event buffers


def apply_events(index: ClauseIndex, events: Event) -> ClauseIndex:
    """Replay a fixed-shape, masked event buffer; each event is O(1).

    The *sequential oracle*: one ``lax.scan`` iteration per buffer slot,
    exactly the paper's one-event-at-a-time pointer algebra. The production
    path is :func:`index_update` (batched replay, no scan) — property tests
    pin the two equivalent on membership, counts (incl. overflow) and the
    lists↔pos bijection; this body stays as the semantics reference.
    """

    def body(idx, ev):
        def do(idx):
            return jax.lax.cond(
                ev.is_insert,
                lambda ix: insert(ix, ev.cls, ev.clause, ev.literal),
                lambda ix: delete(ix, ev.cls, ev.clause, ev.literal),
                idx,
            )
        return jax.lax.cond(ev.valid, do, lambda ix: ix, idx), None

    out, _ = jax.lax.scan(body, index, events)
    return out


def index_update(index: ClauseIndex, events: Event,
                 backend: str = "auto") -> ClauseIndex:
    """Batched event replay — the production form of :func:`apply_events`.

    Routes the ``index_update`` registry primitive (``kernels/indexed.py``):
    the whole buffer lands in a handful of vectorised scatters instead of a
    serialised scan, order-equivalent to sequential replay (identical
    membership/counts/bijection; intra-list slot order is the one
    unobservable difference — see the kernel docstring's ordering argument).
    Shard-local under shard_map exactly like ``apply_events`` was: every
    operand spec in the primitive's partitioning contract mirrors the
    indexed engine's ``cache_pspec``.
    """
    from repro.kernels.backend import resolve  # lazy: kernels/ is core-free

    fn = resolve("index_update", backend)
    lists, counts, pos = fn(
        index.lists, index.counts, index.pos,
        events.cls, events.clause, events.literal,
        events.is_insert, events.valid)
    return ClauseIndex(lists=lists, counts=counts, pos=pos)


class EventBuffer(NamedTuple):
    """A fixed-capacity masked event buffer + its overflow counter.

    ``overflow`` counts the boundary crossings that did **not** fit in the
    buffer — dropped events leave every derived cache silently stale, so a
    non-zero counter is a config error (``max_events`` too small for the
    batch). The counter makes that failure observable for the cost of one
    scalar: callers assert ``overflow == 0`` after a step instead of sizing
    buffers to the ``n_classes·n_clauses·n_literals`` worst case up front
    (``TMBundle.event_overflow`` accumulates it across steps).
    """

    events: Event       # (max_events,) leaves
    overflow: jax.Array # () int32 — changed cells beyond capacity


def events_from_transition(
    old_include: jax.Array, new_include: jax.Array, max_events: int
) -> EventBuffer:
    """Diff two include masks into a fixed-capacity counted event buffer.

    Used by the learning loop to keep the index in sync after feedback:
    the TM updates states densely (TPU-friendly), then the index absorbs
    only the boundary crossings — exactly the events the paper's CPU
    implementation applies one by one.

    Selection is two cumsums + one scatter, not a sort: cell i's buffer
    slot is its rank among changed cells (changed) or ``total`` plus its
    rank among unchanged ones (padding), which reproduces the stable
    ``argsort(~changed)[:max_events]`` bit-for-bit — first ``max_events``
    changed cells in ascending cell order, then ascending unchanged fill —
    at O(cells) work instead of a full O(cells·log) sort every train step
    (regression-pinned in tests/test_tm_indexing.py).
    """
    changed = old_include != new_include                 # (m, n, 2o)
    flat = changed.reshape(-1)
    m, n, L = old_include.shape
    total = jnp.sum(flat, dtype=jnp.int32)
    # a buffer longer than the cell count degenerates to "all cells",
    # matching the old ``order[:max_events]`` slice semantics
    max_events = min(max_events, flat.shape[0])
    ranks = jnp.cumsum(flat.astype(jnp.int32)) - 1       # rank among changed
    pad_ranks = total + jnp.cumsum((~flat).astype(jnp.int32)) - 1
    slot = jnp.where(flat, ranks, pad_ranks)             # bijection on cells
    sel = jnp.zeros((max_events,), jnp.int32).at[slot].set(
        jnp.arange(flat.shape[0], dtype=jnp.int32), mode="drop")
    valid = flat[sel]
    cls, rem = jnp.divmod(sel, n * L)
    clause, literal = jnp.divmod(rem, L)
    is_insert = new_include.reshape(-1)[sel]
    return EventBuffer(
        events=Event(
            cls=cls.astype(jnp.int32),
            clause=clause.astype(jnp.int32),
            literal=literal.astype(jnp.int32),
            is_insert=is_insert,
            valid=valid,
        ),
        overflow=jnp.maximum(total - max_events, 0).astype(jnp.int32),
    )


# ---------------------------------------------------------------------------
# Index-based inference (paper §3 "Index Based Inference", Eq. 4)
# ---------------------------------------------------------------------------


def indexed_partial_scores(
    index: ClauseIndex, x: jax.Array, pol: jax.Array
) -> jax.Array:
    """(B, o) inputs + per-clause ±1 polarity → (B, m) partial vote sums.

    The shard-local form of Eq. 4: for each false literal k, the clauses in
    L[i,k] are falsified; the contribution is ``-Σ_{j falsified} pol_j``
    (= |C_F^-| - |C_F^+| over the clauses this index covers). With a
    *clause-sharded* index — every shard owns its own lists over its own
    clause ids — the falsified-union is shard-local and the partial sums add,
    so one psum over the clause axis reproduces the global Eq. 4 scores
    exactly (Σ pol = 0 over all clauses maps Eq. 3 votes onto Eq. 4).

    Body: the matmul form over the position matrix — ``pos != NA`` is the
    membership mask, so the falsified-union is one contraction (the
    ``indexed_votes`` XLA reference body; the engine resolves the same
    primitive per ``cfg.backend`` to run the fused Pallas kernel instead).
    The old per-sample vmap → (m, 2o, cap) scatter-max is gone.
    """
    from repro.kernels import indexed as kindexed  # lazy: mirror backend use

    return kindexed.indexed_votes_xla(index.pos, literals_from_input(x), pol)


def indexed_scores(cfg: TMConfig, index: ClauseIndex, x: jax.Array) -> jax.Array:
    """(B, o) inputs → (B, m) scores via falsification look-up.

    Scores are |C_F^-| - |C_F^+| (Eq. 4), which equals the vote sum of Eq. 3
    shifted by a per-class constant when empty clauses count as true —
    ``argmax`` is unchanged; tests pin exact equality of scores against the
    dense path with ``empty_clause_output=1``.
    """
    from repro.core.types import clause_polarity

    return indexed_partial_scores(index, x, clause_polarity(cfg))


def indexed_work(index: ClauseIndex, x: jax.Array) -> jax.Array:
    """The paper's work metric: Σ_{k false} |L[i,k]| summed over classes.

    Used by benchmarks to reproduce the 0.02 (MNIST) / 0.006 (IMDb)
    work-ratio claims (§3 'Remarks').
    """
    lit = literals_from_input(x)
    false_lit = (lit == 0).astype(jnp.int32)              # (B, 2o)
    return jnp.einsum("bk,mk->b", false_lit, index.counts)


def dense_work(cfg: TMConfig) -> int:
    """Work of exhaustive evaluation: m·n·2o literal inspections."""
    return cfg.n_classes * cfg.n_clauses * cfg.n_literals


# ---------------------------------------------------------------------------
# Clause-compact (transpose) layout — TPU gather evaluation
# ---------------------------------------------------------------------------


class CompactClauses(NamedTuple):
    lit_idx: jax.Array  # (m, n, l_max) int32 literal indices; NA padded
    lengths: jax.Array  # (m, n) int32


def compact(cfg: TMConfig, state: TMState, l_max: int) -> CompactClauses:
    """Include mask → per-clause included-literal index rows."""
    inc = include_mask(cfg, state)                        # (m, n, 2o)
    lengths = inc.sum(-1).astype(jnp.int32)
    slot = jnp.cumsum(inc.astype(jnp.int32), axis=-1) - 1
    m, n, L = inc.shape
    lit_ids = jnp.broadcast_to(jnp.arange(L, dtype=jnp.int32), (m, n, L))
    safe_slot = jnp.where(inc, slot, l_max)
    lit_idx = jnp.full((m, n, l_max), NA, jnp.int32)
    lit_idx = lit_idx.at[
        jnp.arange(m)[:, None, None],
        jnp.arange(n)[None, :, None],
        safe_slot,
    ].set(jnp.where(inc, lit_ids, NA), mode="drop")
    return CompactClauses(lit_idx=lit_idx, lengths=lengths)


def compact_eval(
    cfg: TMConfig, comp: CompactClauses, x: jax.Array
) -> jax.Array:
    """(B, o) → (B, m, n) clause outputs touching only included literals.

    Work: B·m·n·l_max gathers vs B·m·n·2o dense — the paper's ratio
    (avg clause length / 2o ≈ 58/1568 ≈ 0.037 on MNIST). Empty clauses
    evaluate true (paper Eq. 4 semantics).
    """
    lit = literals_from_input(x)                          # (B, 2o)
    safe = jnp.where(comp.lit_idx == NA, 0, comp.lit_idx) # (m, n, l_max)
    gathered = lit[:, safe]                               # (B, m, n, l_max)
    ok = (gathered == 1) | (comp.lit_idx == NA)[None]
    return jnp.all(ok, axis=-1).astype(jnp.uint8)


def compact_scores(cfg: TMConfig, comp: CompactClauses, x: jax.Array) -> jax.Array:
    from repro.core.tm import clause_votes

    return clause_votes(cfg, compact_eval(cfg, comp, x))


def compact_apply_events(comp: CompactClauses, events: Event) -> CompactClauses:
    """Replay include/exclude events on the clause-compact layout.

    The transpose of ``apply_events``: rows are *clauses* holding literal ids,
    so an insert appends the literal, a delete is the same swap-with-last the
    paper uses for its lists. Rows are sets — ``compact_eval`` is order-blind —
    so event replay and a fresh ``compact()`` build agree up to row order.

    Contract (the TMBundle sync contract, DESIGN.md): events must be diffed
    against exactly the state this cache was built from. Capacity overflow
    loses the overflowing literal (a config error, surfaced by
    ``validate_compact``) but never corrupts surviving entries: an insert
    past ``ℓ_max`` leaves ``lengths`` clamped, and a delete of a literal the
    row never absorbed is a no-op.
    """
    l_max = comp.lit_idx.shape[-1]

    def body(c, ev):
        def do_insert(c):
            slot = c.lengths[ev.cls, ev.clause]
            fits = slot < l_max
            lit_idx = c.lit_idx.at[ev.cls, ev.clause, slot].set(
                ev.literal.astype(jnp.int32), mode="drop")
            lengths = c.lengths.at[ev.cls, ev.clause].add(
                jnp.where(fits, 1, 0))
            return CompactClauses(lit_idx=lit_idx, lengths=lengths)

        def do_delete(c):
            row = c.lit_idx[ev.cls, ev.clause]            # (l_max,)
            hit = row == ev.literal.astype(jnp.int32)
            present = jnp.any(hit)
            p = jnp.argmax(hit)
            last = c.lengths[ev.cls, ev.clause] - 1
            moved = row[last]
            lit_idx = c.lit_idx.at[ev.cls, ev.clause, p].set(
                jnp.where(present, moved, row[p]))
            lit_idx = lit_idx.at[ev.cls, ev.clause, last].set(
                jnp.where(present, NA, moved))
            lengths = c.lengths.at[ev.cls, ev.clause].add(
                jnp.where(present, -1, 0))
            return CompactClauses(lit_idx=lit_idx, lengths=lengths)

        def do(c):
            return jax.lax.cond(ev.is_insert, do_insert, do_delete, c)

        return jax.lax.cond(ev.valid, do, lambda c: c, c), None

    out, _ = jax.lax.scan(body, comp, events)
    return out


def validate_compact(cfg: TMConfig, state: TMState,
                     comp: CompactClauses) -> dict:
    """Invariant checks for the clause-compact layout (cf. ``validate``).

    ``lengths_ok`` fails when capacity overflow has lost literals —
    ``lengths`` can only track true clause lengths while they fit ℓ_max.
    """
    inc = include_mask(cfg, state)                       # (m, n, 2o)
    true_lengths = inc.sum(-1).astype(jnp.int32)
    lengths_ok = jnp.all(comp.lengths == true_lengths)
    overflow_ok = jnp.all(comp.lengths <= comp.lit_idx.shape[-1])
    # membership: every non-NA entry is an included literal of its clause
    m, n, L = inc.shape
    safe = jnp.where(comp.lit_idx == NA, 0, comp.lit_idx)
    back = inc[jnp.arange(m)[:, None, None],
               jnp.arange(n)[None, :, None], safe]       # (m, n, l_max)
    member_ok = jnp.all(jnp.where(comp.lit_idx != NA, back, True))
    slot_valid = (jnp.arange(comp.lit_idx.shape[-1])[None, None, :]
                  < comp.lengths[..., None])
    padding_ok = jnp.all(jnp.where(slot_valid, True, comp.lit_idx == NA))
    return dict(lengths_ok=lengths_ok, overflow_ok=overflow_ok,
                member_ok=member_ok, padding_ok=padding_ok)
