"""TM at datacenter scale (beyond-paper): clause-sharded evaluation.

The paper targets one CPU. The TM's vote structure is embarrassingly
shardable: clauses over ``model`` (each shard owns n/16 clauses of every
class), batch over ``data``/``pod``. Votes are partial sums reduced over
``model`` — GSPMD inserts one (B, m)-sized all-reduce, the only collective.

Learning shards the same way: Type I/II feedback is per-clause-local given
the per-class vote (the one all-reduce), so TA-state updates never move.
The dry-run lowers this on the production meshes (launch/dryrun.py --tm).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core import tm
from repro.core.types import TMConfig


def tm_shardings(cfg: TMConfig, mesh):
    """(state_sharding, batch_sharding, votes_sharding)."""
    baxes = ("pod", "data") if "pod" in mesh.axis_names else ("data",)
    state = NamedSharding(mesh, P(None, "model", None))   # (m, n, 2o)
    x = NamedSharding(mesh, P(baxes, None))               # (B, o)
    y = NamedSharding(mesh, P(baxes))
    votes = NamedSharding(mesh, P(baxes, None))           # (B, m)
    return state, x, y, votes


def make_sharded_votes(cfg: TMConfig, mesh):
    """jit'd (ta_state, x) → (B, m) votes on the production mesh."""
    state_sh, x_sh, _, votes_sh = tm_shardings(cfg, mesh)

    def fn(ta_state, x):
        from repro.core.types import TMState
        return tm.scores(cfg, TMState(ta_state=ta_state), x)

    return jax.jit(fn, in_shardings=(state_sh, x_sh),
                   out_shardings=votes_sh)


def make_sharded_update(cfg: TMConfig, mesh):
    """jit'd batch-parallel TM update, clause-sharded.

    Uses the batch-parallel learning variant (DESIGN.md §2): per-sample
    deltas against the pre-batch state, summed — the approximation that
    makes TM learning batch-shardable at all.
    """
    state_sh, x_sh, y_sh, _ = tm_shardings(cfg, mesh)

    def fn(ta_state, xs, ys, seed):
        from repro.core.types import TMState
        st = TMState(ta_state=ta_state)
        new = tm.update_batch_parallel(cfg, st, xs, ys,
                                       jax.random.key(seed[0]))
        return new.ta_state

    seed_sh = NamedSharding(mesh, P(None))
    return jax.jit(fn, in_shardings=(state_sh, x_sh, y_sh, seed_sh),
                   out_shardings=state_sh, donate_argnums=(0,))
