"""Clause-sharded TMBundle execution — TM at datacenter scale (beyond-paper).

The paper targets one CPU. The Massively Parallel TM line (Abeyrathna et
al., 2020) shows the scaling recipe: partition *clauses* across workers,
evaluate shard-locally, reduce the per-class vote once. This module is that
recipe over the PR-1 engine registry, so the sharded unit is the whole
``TMBundle`` — TA state *and* every engine cache — not a bare ``ta_state``:

  * every ``EvalEngine`` declares how its cache partitions over the mesh
    clause axis (``cache_pspec``), builds its shard-local cache from a
    clause shard of the state (``shard_prepare``), and evaluates partial
    votes (``partial_scores``);
  * ``make_sharded_scores`` psums the partials over ``CLAUSE_AXIS`` — the
    single (B, m) vote all-reduce, the *only* collective in the lowered HLO
    (asserted by ``launch/dryrun.py --tm``); batch shards over the data/pod
    axes with no communication at all;
  * ``make_sharded_train_step`` runs dense Type I/II feedback on each
    shard's clause slice (feedback is clause-local given the vote — the
    vote psum inside ``tm._class_round`` is again the only collective),
    then diffs the *local* include mask and replays the events into the
    shard-local caches: event-driven cache sync never leaves the shard.

Randomness: every shard draws the identical full-size uniforms and slices
its clause rows (``tm._slice_rands``), so sharded training is **bit-exact**
with the single-device path — the property tests/test_tm_sharded.py pins
for every registered engine on a forced 8-device host mesh.

Shard-local cache layouts: caches whose arrays carry the clause axis
(packed words, compact rows, the position matrix) tile into the global
array exactly; per-shard structures with no clause axis of their own (the
index's lists capacity rows and counts) tile as opaque blocks along
``CLAUSE_AXIS`` — the assembled global array is storage, only ever
interpreted through shard_map with the engine's declared spec. The indexed
engine's shard therefore owns complete falsification lists over *its own*
clauses (local ids), which is what makes the falsified-union shard-local
and the partial votes additive.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core import indexing, tm
from repro.core.api import (
    DEFAULT_ENGINE, TMBundle, cache_keys_for, resolve_donate)
from repro.core.engines import CLAUSE_AXIS, cache_provider, get_engine
from repro.core.types import TMConfig, TMState, clause_polarity, include_mask
from repro.sharding import shard_map_compat

STATE_PSPEC = TMState(ta_state=P(None, CLAUSE_AXIS, None))


def batch_axes(mesh) -> tuple[str, ...]:
    """Mesh axes the batch shards over (pod-major, matching P ordering)."""
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def clause_shards(mesh) -> int:
    if CLAUSE_AXIS not in mesh.axis_names:
        raise ValueError(
            f"mesh {mesh.axis_names} has no {CLAUSE_AXIS!r} axis to shard "
            "clauses over")
    return mesh.shape[CLAUSE_AXIS]


def _check_mesh(cfg: TMConfig, mesh) -> int:
    shards = clause_shards(mesh)
    if cfg.n_clauses % shards:
        raise ValueError(
            f"n_clauses={cfg.n_clauses} must divide by the {shards}-way "
            f"{CLAUSE_AXIS!r} axis")
    return shards


def bundle_pspecs(cfg: TMConfig, engines=None):
    """(state_pspec, {cache_key: cache_pspec}) for a sharded bundle."""
    return STATE_PSPEC, {key: cache_provider(key).cache_pspec(cfg)
                         for key in cache_keys_for(engines)}


def _sharded_polarity(cfg: TMConfig, mesh) -> jax.Array:
    return jax.device_put(clause_polarity(cfg),
                          NamedSharding(mesh, P(CLAUSE_AXIS)))


def make_sharded_prepare(cfg: TMConfig, mesh, *, engines=None):
    """``(TMState) -> TMBundle`` with shard-local caches for every engine.

    The state lands clause-sharded (``STATE_PSPEC``); each distinct cache
    slot is built *on its shard* from the local state slice — no device ever
    materialises a full cache.
    """
    shards = _check_mesh(cfg, mesh)
    keys = cache_keys_for(engines)
    state_sh = NamedSharding(mesh, STATE_PSPEC.ta_state)
    _, cache_specs = bundle_pspecs(cfg, engines)

    def local_fn(state_l: TMState):
        return {k: cache_provider(k).shard_prepare(cfg, state_l, shards)
                for k in keys}

    fn = jax.jit(shard_map_compat(local_fn, mesh=mesh,
                                  in_specs=(STATE_PSPEC,),
                                  out_specs=cache_specs))

    def prepare(state: TMState) -> TMBundle:
        state = TMState(ta_state=jax.device_put(state.ta_state, state_sh))
        caches = fn(state) if keys else {}
        return TMBundle(cfg=cfg, state=state, caches=caches,
                        event_overflow=jnp.zeros((), jnp.int32))

    return prepare


def make_sharded_scores(cfg: TMConfig, mesh, *, engine: str = DEFAULT_ENGINE):
    """``(TMBundle, x) -> (B, m)`` scores through one engine, clause-sharded.

    Exactly one collective: the psum of per-shard partial votes (GSPMD
    lowers it to a single (B, m) all-reduce over ``CLAUSE_AXIS``). The batch
    shards over the data/pod axes communication-free.
    """
    _check_mesh(cfg, mesh)
    eng = get_engine(engine)
    baxes = batch_axes(mesh)
    bspec = P(baxes, None) if baxes else P(None, None)
    cache_spec = eng.cache_pspec(cfg)
    pol = _sharded_polarity(cfg, mesh)

    def local_fn(cache_l, pol_l, x_l):
        part = eng.partial_scores(cfg, cache_l, x_l, pol_l)
        return jax.lax.psum(part, CLAUSE_AXIS)

    fn = jax.jit(shard_map_compat(
        local_fn, mesh=mesh, in_specs=(cache_spec, P(CLAUSE_AXIS), bspec),
        out_specs=bspec))

    def scores(bundle: TMBundle, x: jax.Array) -> jax.Array:
        if not eng.needs_cache:
            return fn(bundle.state, pol, x)
        cache = bundle.caches.get(eng.cache_key)
        if cache is None:
            raise KeyError(
                f"engine {engine!r} (cache slot {eng.cache_key!r}) was not "
                f"prepared in this bundle (slots: {tuple(bundle.caches)}); "
                "include it in the engines= of make_sharded_prepare / the "
                "TMSession — sharded caches cannot be built on the fly")
        return fn(cache, pol, x)

    # exposed for the dry-run's HLO assertions (launch/dryrun.py --tm)
    scores.jitted, scores.pol, scores.engine = fn, pol, eng
    return scores


def make_sharded_train_step(cfg: TMConfig, mesh, *, engines=None,
                            parallel: bool = False, max_events: int = 4096,
                            donate: bool | None = None):
    """``(TMBundle, xs, ys, rng[, mask]) -> TMBundle``, sharded end to end.

    Sequential mode keeps the paper's global sample order (online learning
    is sequential in samples by definition), so the data/pod axes cannot
    shard the *batch* — instead they compose with the clause axis
    **hierarchically**: when the per-shard clause count divides by the
    data-axis size, each data rank scans the full batch over its own clause
    *sub-slice* (global clause order = model-major, data-minor), and one
    final psum over the data axes reassembles the model-shard slice. The
    vote psum inside ``tm._class_round`` then runs over *all* mesh axes —
    it already composed; the batch-order question is answered by giving the
    data axis clause work, not batch work. The batch-parallel approximation
    shards the batch over data/pod as before, psumming the summed TA
    deltas. Either way every collective is an all-reduce; the include-mask
    diff and every cache's event replay stay on the model shard
    (``max_events`` bounds the *per-shard* event buffer). Bit-exact with
    the single-device ``api.train_step`` (identical randomness via
    full-draw slicing).

    ``mask`` (B,) bool marks valid samples (the fixed-shape padding
    contract of ``api.train_step``); omitted → all rows valid.
    """
    shards = _check_mesh(cfg, mesh)
    n_local = cfg.n_clauses // shards
    keys = cache_keys_for(engines)
    _, cache_specs = bundle_pspecs(cfg, engines)
    all_baxes = batch_axes(mesh)
    d_shards = math.prod(mesh.shape[a] for a in all_baxes) if all_baxes else 1
    # sequential: hierarchical data×clause composition when divisible
    compose = (not parallel) and d_shards > 1 and n_local % d_shards == 0
    n_sub = n_local // d_shards if compose else n_local
    baxes = all_baxes if parallel else ()
    x_spec = P(baxes, None) if baxes else P(None, None)
    y_spec = P(baxes) if baxes else P(None)
    pol = _sharded_polarity(cfg, mesh)

    def local_fn(state_l: TMState, caches_l, pol_l, xs, ys, key_data, mask,
                 overflow_in):
        rng = jax.random.wrap_key_data(key_data)
        start = jax.lax.axis_index(CLAUSE_AXIS) * n_local
        old_inc = include_mask(cfg, state_l)
        if parallel:
            b_idx = jnp.int32(0)
            for a in baxes:
                b_idx = b_idx * mesh.shape[a] + jax.lax.axis_index(a)
            b_total = (xs.shape[0] * math.prod(mesh.shape[a] for a in baxes)
                       if baxes else None)
            new_state = tm.update_batch_parallel(
                cfg, state_l, xs, ys, rng, pol=pol_l, axis_name=CLAUSE_AXIS,
                clause_start=start, batch_axes=baxes,
                batch_start=b_idx * xs.shape[0], batch_total=b_total,
                mask=mask)
        elif compose:
            # this data rank owns clause rows [d·n_sub, (d+1)·n_sub) of the
            # model shard's slice; votes psum over (data axes + clause axis)
            d_idx = jnp.int32(0)
            for a in all_baxes:
                d_idx = d_idx * mesh.shape[a] + jax.lax.axis_index(a)
            off = d_idx * n_sub
            sub = TMState(ta_state=jax.lax.dynamic_slice_in_dim(
                state_l.ta_state, off, n_sub, 1))
            pol_sub = jax.lax.dynamic_slice_in_dim(pol_l, off, n_sub, 0)
            new_sub = tm.update_batch_sequential(
                cfg, sub, xs, ys, rng, pol=pol_sub,
                axis_name=(*all_baxes, CLAUSE_AXIS),
                clause_start=start + off, mask=mask)
            # reassemble the model shard's slice: each row is owned by
            # exactly one data rank, so a zero-padded psum is a gather
            # expressed as the one collective kind this step allows
            assembled = jax.lax.dynamic_update_slice_in_dim(
                jnp.zeros_like(state_l.ta_state), new_sub.ta_state, off, 1)
            new_state = TMState(
                ta_state=jax.lax.psum(assembled, all_baxes))
        else:
            new_state = tm.update_batch_sequential(
                cfg, state_l, xs, ys, rng, pol=pol_l, axis_name=CLAUSE_AXIS,
                clause_start=start, mask=mask)
        buf = indexing.events_from_transition(
            old_inc, include_mask(cfg, new_state), max_events)
        new_caches = {k: cache_provider(k).update_cache(
                          cfg, caches_l[k], new_state, buf.events)
                      for k in keys}
        # per-shard drop counts add over the clause axis (each model shard
        # diffs only its own include slice; data ranks see identical diffs),
        # yielding the replicated global overflow counter — an all-reduce,
        # never a gather, per the step's collective contract
        overflow = overflow_in + jax.lax.psum(buf.overflow, CLAUSE_AXIS)
        return new_state, new_caches, overflow

    mask_spec = y_spec  # batch-sharded in parallel mode, replicated otherwise
    sm = shard_map_compat(
        local_fn, mesh=mesh,
        in_specs=(STATE_PSPEC, cache_specs, P(CLAUSE_AXIS), x_spec, y_spec,
                  P(None), mask_spec, P()),
        out_specs=(STATE_PSPEC, cache_specs, P()))
    donate_nums = (0, 1) if resolve_donate(donate) else ()
    fn = jax.jit(sm, donate_argnums=donate_nums)

    def step(bundle: TMBundle, xs, ys, rng, mask=None) -> TMBundle:
        if mask is None:
            mask = jnp.ones(xs.shape[0], bool)
        overflow_in = (bundle.event_overflow
                       if bundle.event_overflow is not None
                       else jnp.zeros((), jnp.int32))
        new_state, new_caches, overflow = fn(
            bundle.state, bundle.caches, pol, xs, ys,
            jax.random.key_data(rng), mask, overflow_in)
        return TMBundle(cfg=cfg, state=new_state, caches=new_caches,
                        event_overflow=overflow)

    # exposed for the dry-run's HLO assertions (launch/dryrun.py --tm)
    step.jitted, step.pol, step.composes_data_axis = fn, pol, compose
    return step


# The stateful facade over these factories is ``core/session.py``'s
# ``TMSession`` (``ShardedTM`` in PR 2): one session resolves a ``Topology``
# into either this shard_map path or the single-device jitted path, so
# callers never wire prepare/scores/train_step by hand.
