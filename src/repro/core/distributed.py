"""Clause-sharded TMBundle execution — TM at datacenter scale (beyond-paper).

The paper targets one CPU. The Massively Parallel TM line (Abeyrathna et
al., 2020) shows the scaling recipe: partition *clauses* across workers,
evaluate shard-locally, reduce the per-class vote once. This module is that
recipe over the PR-1 engine registry, so the sharded unit is the whole
``TMBundle`` — TA state *and* every engine cache — not a bare ``ta_state``:

  * every ``EvalEngine`` declares how its cache partitions over the mesh
    clause axis (``cache_pspec``), builds its shard-local cache from a
    clause shard of the state (``shard_prepare``), and evaluates partial
    votes (``partial_scores``);
  * ``make_sharded_scores`` psums the partials over ``CLAUSE_AXIS`` — the
    single (B, m) vote all-reduce, the *only* collective in the lowered HLO
    (asserted by ``launch/dryrun.py --tm``); batch shards over the data/pod
    axes with no communication at all;
  * ``make_sharded_train_step`` runs dense Type I/II feedback on each
    shard's clause slice (feedback is clause-local given the vote — the
    vote psum inside ``tm._class_round`` is again the only collective),
    then diffs the *local* include mask and replays the events into the
    shard-local caches: event-driven cache sync never leaves the shard.

Randomness: every shard draws the identical full-size uniforms and slices
its clause rows (``tm._slice_rands``), so sharded training is **bit-exact**
with the single-device path — the property tests/test_tm_sharded.py pins
for every registered engine on a forced 8-device host mesh.

Shard-local cache layouts: caches whose arrays carry the clause axis
(packed words, compact rows, the position matrix) tile into the global
array exactly; per-shard structures with no clause axis of their own (the
index's lists capacity rows and counts) tile as opaque blocks along
``CLAUSE_AXIS`` — the assembled global array is storage, only ever
interpreted through shard_map with the engine's declared spec. The indexed
engine's shard therefore owns complete falsification lists over *its own*
clauses (local ids), which is what makes the falsified-union shard-local
and the partial votes additive.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core import indexing, tm
from repro.core.api import DEFAULT_ENGINE, TMBundle, cache_keys_for
from repro.core.engines import (
    CLAUSE_AXIS, cache_provider, get_engine, registered_engines)
from repro.core.types import TMConfig, TMState, clause_polarity, include_mask
from repro.sharding import shard_map_compat

STATE_PSPEC = TMState(ta_state=P(None, CLAUSE_AXIS, None))


def batch_axes(mesh) -> tuple[str, ...]:
    """Mesh axes the batch shards over (pod-major, matching P ordering)."""
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def clause_shards(mesh) -> int:
    if CLAUSE_AXIS not in mesh.axis_names:
        raise ValueError(
            f"mesh {mesh.axis_names} has no {CLAUSE_AXIS!r} axis to shard "
            "clauses over")
    return mesh.shape[CLAUSE_AXIS]


def _check_mesh(cfg: TMConfig, mesh) -> int:
    shards = clause_shards(mesh)
    if cfg.n_clauses % shards:
        raise ValueError(
            f"n_clauses={cfg.n_clauses} must divide by the {shards}-way "
            f"{CLAUSE_AXIS!r} axis")
    return shards


def bundle_pspecs(cfg: TMConfig, engines=None):
    """(state_pspec, {cache_key: cache_pspec}) for a sharded bundle."""
    return STATE_PSPEC, {key: cache_provider(key).cache_pspec(cfg)
                         for key in cache_keys_for(engines)}


def _sharded_polarity(cfg: TMConfig, mesh) -> jax.Array:
    return jax.device_put(clause_polarity(cfg),
                          NamedSharding(mesh, P(CLAUSE_AXIS)))


def make_sharded_prepare(cfg: TMConfig, mesh, *, engines=None):
    """``(TMState) -> TMBundle`` with shard-local caches for every engine.

    The state lands clause-sharded (``STATE_PSPEC``); each distinct cache
    slot is built *on its shard* from the local state slice — no device ever
    materialises a full cache.
    """
    shards = _check_mesh(cfg, mesh)
    keys = cache_keys_for(engines)
    state_sh = NamedSharding(mesh, STATE_PSPEC.ta_state)
    _, cache_specs = bundle_pspecs(cfg, engines)

    def local_fn(state_l: TMState):
        return {k: cache_provider(k).shard_prepare(cfg, state_l, shards)
                for k in keys}

    fn = jax.jit(shard_map_compat(local_fn, mesh=mesh,
                                  in_specs=(STATE_PSPEC,),
                                  out_specs=cache_specs))

    def prepare(state: TMState) -> TMBundle:
        state = TMState(ta_state=jax.device_put(state.ta_state, state_sh))
        caches = fn(state) if keys else {}
        return TMBundle(cfg=cfg, state=state, caches=caches)

    return prepare


def make_sharded_scores(cfg: TMConfig, mesh, *, engine: str = DEFAULT_ENGINE):
    """``(TMBundle, x) -> (B, m)`` scores through one engine, clause-sharded.

    Exactly one collective: the psum of per-shard partial votes (GSPMD
    lowers it to a single (B, m) all-reduce over ``CLAUSE_AXIS``). The batch
    shards over the data/pod axes communication-free.
    """
    _check_mesh(cfg, mesh)
    eng = get_engine(engine)
    baxes = batch_axes(mesh)
    bspec = P(baxes, None) if baxes else P(None, None)
    cache_spec = eng.cache_pspec(cfg)
    pol = _sharded_polarity(cfg, mesh)

    def local_fn(cache_l, pol_l, x_l):
        part = eng.partial_scores(cfg, cache_l, x_l, pol_l)
        return jax.lax.psum(part, CLAUSE_AXIS)

    fn = jax.jit(shard_map_compat(
        local_fn, mesh=mesh, in_specs=(cache_spec, P(CLAUSE_AXIS), bspec),
        out_specs=bspec))

    def scores(bundle: TMBundle, x: jax.Array) -> jax.Array:
        if not eng.needs_cache:
            return fn(bundle.state, pol, x)
        cache = bundle.caches.get(eng.cache_key)
        if cache is None:
            raise KeyError(
                f"engine {engine!r} (cache slot {eng.cache_key!r}) was not "
                f"prepared in this bundle (slots: {tuple(bundle.caches)}); "
                "include it in the engines= of make_sharded_prepare/"
                "ShardedTM — sharded caches cannot be built on the fly")
        return fn(cache, pol, x)

    # exposed for the dry-run's HLO assertions (launch/dryrun.py --tm)
    scores.jitted, scores.pol, scores.engine = fn, pol, eng
    return scores


def make_sharded_train_step(cfg: TMConfig, mesh, *, engines=None,
                            parallel: bool = False, max_events: int = 4096):
    """``(TMBundle, xs, ys, rng) -> TMBundle``, clause-sharded end to end.

    Sequential mode scans the full batch on every shard (online learning is
    sequential in samples by definition); the batch-parallel approximation
    additionally shards the batch over the data/pod axes, psumming the
    summed TA deltas. Either way the per-class vote psum inside
    ``tm._class_round`` is the only cross-shard traffic — the include-mask
    diff and every cache's event replay stay on the shard (``max_events``
    bounds the *per-shard* event buffer). Bit-exact with the single-device
    ``api.train_step`` (identical randomness via full-draw slicing).
    """
    shards = _check_mesh(cfg, mesh)
    n_local = cfg.n_clauses // shards
    keys = cache_keys_for(engines)
    _, cache_specs = bundle_pspecs(cfg, engines)
    baxes = batch_axes(mesh) if parallel else ()
    x_spec = P(baxes, None) if baxes else P(None, None)
    y_spec = P(baxes) if baxes else P(None)
    pol = _sharded_polarity(cfg, mesh)

    def local_fn(state_l: TMState, caches_l, pol_l, xs, ys, key_data):
        rng = jax.random.wrap_key_data(key_data)
        start = jax.lax.axis_index(CLAUSE_AXIS) * n_local
        old_inc = include_mask(cfg, state_l)
        if parallel:
            b_idx = jnp.int32(0)
            for a in baxes:
                b_idx = b_idx * mesh.shape[a] + jax.lax.axis_index(a)
            b_total = (xs.shape[0] * math.prod(mesh.shape[a] for a in baxes)
                       if baxes else None)
            new_state = tm.update_batch_parallel(
                cfg, state_l, xs, ys, rng, pol=pol_l, axis_name=CLAUSE_AXIS,
                clause_start=start, batch_axes=baxes,
                batch_start=b_idx * xs.shape[0], batch_total=b_total)
        else:
            new_state = tm.update_batch_sequential(
                cfg, state_l, xs, ys, rng, pol=pol_l, axis_name=CLAUSE_AXIS,
                clause_start=start)
        events = indexing.events_from_transition(
            old_inc, include_mask(cfg, new_state), max_events)
        new_caches = {k: cache_provider(k).update_cache(
                          cfg, caches_l[k], new_state, events) for k in keys}
        return new_state, new_caches

    sm = shard_map_compat(
        local_fn, mesh=mesh,
        in_specs=(STATE_PSPEC, cache_specs, P(CLAUSE_AXIS), x_spec, y_spec,
                  P(None)),
        out_specs=(STATE_PSPEC, cache_specs))
    donate = (0, 1) if jax.default_backend() != "cpu" else ()
    fn = jax.jit(sm, donate_argnums=donate)

    def step(bundle: TMBundle, xs, ys, rng) -> TMBundle:
        new_state, new_caches = fn(bundle.state, bundle.caches, pol, xs, ys,
                                   jax.random.key_data(rng))
        return TMBundle(cfg=cfg, state=new_state, caches=new_caches)

    # exposed for the dry-run's HLO assertions (launch/dryrun.py --tm)
    step.jitted, step.pol = fn, pol
    return step


class ShardedTM:
    """One (cfg, mesh) worth of sharded prepare / scores / train_step.

    The distributed counterpart of the ``TsetlinMachine`` facade: factories
    are built once (compilation caches per engine), the bundle flows through
    pure functions exactly like the single-device API.
    """

    def __init__(self, cfg: TMConfig, mesh, *, engines=None,
                 parallel: bool = False, max_events: int = 4096):
        self.cfg = cfg
        self.mesh = mesh
        self.engines = (tuple(engines) if engines is not None
                        else registered_engines())
        self.prepare = make_sharded_prepare(cfg, mesh, engines=self.engines)
        self.train_step = make_sharded_train_step(
            cfg, mesh, engines=self.engines, parallel=parallel,
            max_events=max_events)
        self._scores: dict[str, object] = {}

    def scores(self, bundle: TMBundle, x, *, engine: str = DEFAULT_ENGINE):
        fn = self._scores.get(engine)
        if fn is None:
            fn = make_sharded_scores(self.cfg, self.mesh, engine=engine)
            self._scores[engine] = fn
        return fn(bundle, x)

    def predict(self, bundle: TMBundle, x, *, engine: str = DEFAULT_ENGINE):
        return jnp.argmax(self.scores(bundle, x, engine=engine), axis=-1)
