"""Clause-sharded TMBundle execution — TM at datacenter scale (beyond-paper).

The paper targets one CPU. The Massively Parallel TM line (Abeyrathna et
al., 2020) shows the scaling recipe: partition *clauses* across workers,
evaluate shard-locally, reduce the per-class vote once. This module is that
recipe over the PR-1 engine registry, so the sharded unit is the whole
``TMBundle`` — TA state *and* every engine cache — not a bare ``ta_state``:

  * every ``EvalEngine`` declares how its cache partitions over the mesh
    clause axis (``cache_pspec``), builds its shard-local cache from a
    clause shard of the state (``shard_prepare``), and evaluates partial
    votes (``partial_scores``);
  * ``make_sharded_scores`` psums the partials over ``CLAUSE_AXIS`` — the
    single (B, m) vote all-reduce, the *only* collective in the lowered HLO
    (asserted by ``launch/dryrun.py --tm``); batch shards over the data/pod
    axes with no communication at all;
  * ``make_sharded_train_step`` runs dense Type I/II feedback on each
    shard's clause slice (feedback is clause-local given the vote — the
    vote psum inside ``tm._class_round`` is again the only collective),
    then diffs the *local* include mask and replays the events into the
    shard-local caches: event-driven cache sync never leaves the shard.

Randomness: every shard draws the identical full-size uniforms and slices
its clause rows (``tm._slice_rands``), so sharded training is **bit-exact**
with the single-device path — the property tests/test_tm_sharded.py pins
for every registered engine on a forced 8-device host mesh.

Ragged geometry (DESIGN.md §9): *any* ``(data_shards, clause_shards,
n_clauses)`` is a first-class topology. The clause axis pads up to
``clause_shards · ⌈n_clauses/clause_shards⌉`` rows (``ClauseGeometry``),
and under sequential hierarchical data×clause composition each data rank
owns a zero-padded sub-slice of its clause shard sized
``⌈n_local/data_shards⌉``. Padding rows are *inert by construction*: they
carry sign-0 polarity (zero vote contribution through every engine and
kernel backend), are excluded from the feedback update gate
(``tm`` ``clause_mask`` — the zero ``ta_update`` mask), and the trailing
sub-slice padding is discarded by the reassembly slice, so votes psum and
state reassembly stay bit-exact and all-reduce-only. Only when
``data_shards`` exceeds the per-shard clause count does the sequential
step fall back to batch replication (``composition_rule='replicated'``,
warned once) — there is no clause row left to hand each data rank.

Shard-local cache layouts: caches whose arrays carry the clause axis
(packed words, compact rows, the position matrix) tile into the global
array exactly; per-shard structures with no clause axis of their own (the
index's lists capacity rows and counts) tile as opaque blocks along
``CLAUSE_AXIS`` — the assembled global array is storage, only ever
interpreted through shard_map with the engine's declared spec. The indexed
engine's shard therefore owns complete falsification lists over *its own*
clauses (local ids, dense under padding), which is what makes the
falsified-union shard-local and the partial votes additive — and since the
shard's position-matrix slice carries the same membership information
(``pos != NA`` ⇔ local include), the matmul-form Eq. 4 body
(``indexed_votes``, DESIGN.md §12) evaluates the shard's partial votes
with no list walk at all; batched index maintenance (``index_update``)
replays each shard's own event buffer shard-locally, exactly like the
scan it replaced.
"""
from __future__ import annotations

import dataclasses
import math
import warnings

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core import indexing, tm
from repro.core.api import (
    DEFAULT_ENGINE, TMBundle, cache_keys_for, resolve_donate)
from repro.core.engines import CLAUSE_AXIS, cache_provider, get_engine
from repro.core.types import (
    TMConfig, TMState, VoteAccumulator, clause_polarity, include_mask)
from repro.sharding import shard_map_compat

STATE_PSPEC = TMState(ta_state=P(None, CLAUSE_AXIS, None))

# Sequential-composition rule names (DESIGN.md §9 resolution table); recorded
# by ``dryrun --tm`` and in BENCH_tm_serve.json topology metadata.
COMPOSED_EVEN = "composed_even"      # n_local divides by data_shards
COMPOSED_RAGGED = "composed_ragged"  # ragged sub-slices (zero-padded)
REPLICATED = "replicated"            # data_shards > n_local: PR-2 fallback
CLAUSE_ONLY = "clause_only"          # data_shards == 1: nothing to compose


@dataclasses.dataclass(frozen=True)
class ClauseGeometry:
    """Ragged clause-axis geometry of one ``(cfg × mesh)`` resolution.

    The clause axis pads to ``n_padded = clause_shards · n_local`` rows
    (``n_local = ⌈n_clauses/clause_shards⌉``); rows ``>= n_clauses`` are
    padding, all owned by the trailing shard(s). Under sequential
    data×clause composition each data rank owns ``n_sub =
    ⌈n_local/data_shards⌉`` rows of its shard's (re-padded) slice.
    ``composition`` names the sequential-learning rule that fired —
    ``composed_even`` / ``composed_ragged`` / ``replicated`` /
    ``clause_only`` (DESIGN.md §9).
    """

    n_clauses: int
    clause_shards: int
    data_shards: int
    n_local: int
    n_padded: int
    n_sub: int
    composition: str

    @property
    def ragged_clauses(self) -> bool:
        """True when the global clause axis itself carries padding rows."""
        return self.n_padded != self.n_clauses

    @property
    def composes(self) -> bool:
        """True when sequential learning splits clause work over data ranks."""
        return self.composition in (COMPOSED_EVEN, COMPOSED_RAGGED)

    @property
    def n_sub_padded(self) -> int:
        """Per-shard clause rows after sub-slice padding (≥ ``n_local``)."""
        return self.data_shards * self.n_sub if self.composes else self.n_local

    def shard_rows(self) -> list[dict]:
        """Per-clause-shard row census: ``[{shard, real_rows, pad_rows}]``.

        Padding lands entirely on the trailing shard(s) (§9), so shard ``i``
        owns ``clamp(n_clauses − i·n_local, 0, n_local)`` real rows. Recorded
        in ``TMSession.describe()`` → BENCH_tm_serve.json topology metadata —
        the observability half of the carried-over padding-balance item.
        """
        rows = []
        for i in range(self.clause_shards):
            real = min(max(self.n_clauses - i * self.n_local, 0), self.n_local)
            rows.append({"shard": i, "real_rows": real,
                         "pad_rows": self.n_local - real})
        return rows


def clause_geometry(n_clauses: int, clause_shards: int,
                    data_shards: int) -> ClauseGeometry:
    """Resolve the ragged geometry + sequential composition rule (§9).

    Pure in its three integers, so the resolution table is unit-testable
    without devices; ``geometry`` wraps it for a mesh.
    """
    n_local = -(-n_clauses // clause_shards)
    n_padded = clause_shards * n_local
    if data_shards <= 1:
        rule, n_sub = CLAUSE_ONLY, n_local
    elif n_local % data_shards == 0:
        rule, n_sub = COMPOSED_EVEN, n_local // data_shards
    elif data_shards <= n_local:
        rule, n_sub = COMPOSED_RAGGED, -(-n_local // data_shards)
    else:  # more data ranks than clause rows: no sub-slice to hand out
        rule, n_sub = REPLICATED, n_local
    return ClauseGeometry(
        n_clauses=n_clauses, clause_shards=clause_shards,
        data_shards=data_shards, n_local=n_local, n_padded=n_padded,
        n_sub=n_sub, composition=rule)


def geometry(cfg: TMConfig, mesh) -> ClauseGeometry:
    """``clause_geometry`` of a config on a concrete mesh."""
    shards = clause_shards(mesh)
    baxes = batch_axes(mesh)
    d = math.prod(mesh.shape[a] for a in baxes) if baxes else 1
    return clause_geometry(cfg.n_clauses, shards, d)


def batch_axes(mesh) -> tuple[str, ...]:
    """Mesh axes the batch shards over (pod-major, matching P ordering)."""
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def clause_shards(mesh) -> int:
    """Size of the mesh clause axis; raises when the mesh has none."""
    if CLAUSE_AXIS not in mesh.axis_names:
        raise ValueError(
            f"mesh {mesh.axis_names} has no {CLAUSE_AXIS!r} axis to shard "
            "clauses over")
    return mesh.shape[CLAUSE_AXIS]


def _pad_rows(arr: jax.Array, axis: int, size: int, value) -> jax.Array:
    """Pad ``arr`` along ``axis`` up to ``size`` rows with ``value``."""
    pad = size - arr.shape[axis]
    if pad == 0:
        return arr
    widths = [(0, 0)] * arr.ndim
    widths[axis] = (0, pad)
    return jnp.pad(arr, widths, constant_values=value)


def pad_state(cfg: TMConfig, state: TMState, n_padded: int) -> TMState:
    """Pad the clause axis of a global state to the sharded layout (§9).

    Padding rows sit at state ``n_states`` (every TA excludes ⇒ empty
    clause): their include mask is all-zero, so every engine cache built
    from them is empty and the event diff never sees them; the sharded
    train step freezes them via the clause mask, so the invariant persists.
    Idempotent on an already-padded state.
    """
    n = state.ta_state.shape[1]
    if n == n_padded:
        return state
    if n != cfg.n_clauses:
        raise ValueError(
            f"state has {n} clause rows; expected n_clauses="
            f"{cfg.n_clauses} (unpadded) or {n_padded} (padded)")
    return TMState(ta_state=_pad_rows(
        state.ta_state, 1, n_padded, cfg.n_states))


def unpad_state(cfg: TMConfig, state: TMState) -> TMState:
    """Drop clause-axis padding rows: the global ``(m, n_clauses, 2o)`` view."""
    if state.ta_state.shape[1] == cfg.n_clauses:
        return state
    return TMState(ta_state=state.ta_state[:, :cfg.n_clauses, :])


def bundle_pspecs(cfg: TMConfig, engines=None):
    """(state_pspec, {cache_key: cache_pspec}) for a sharded bundle."""
    return STATE_PSPEC, {key: cache_provider(key).cache_pspec(cfg)
                         for key in cache_keys_for(engines)}


def _sharded_polarity(cfg: TMConfig, mesh) -> jax.Array:
    """Global ±1 polarity, zero-padded to the ragged clause layout.

    Sign 0 is the padding convention every evaluator honours for free: a
    padding clause's output × 0 contributes nothing to any partial vote,
    whether it flows through an XLA body, the fused Pallas votes kernel, or
    the falsification index (empty clauses never enter a list).
    """
    geom = geometry(cfg, mesh)
    pol = _pad_rows(clause_polarity(cfg), 0, geom.n_padded, 0)
    return jax.device_put(pol, NamedSharding(mesh, P(CLAUSE_AXIS)))


def vote_acc_pspec(mesh) -> VoteAccumulator:
    """``VoteAccumulator`` PartitionSpecs: one row per (data × clause) rank.

    The row axis shards jointly over every batch axis and the clause axis
    (pod-major, clause-minor — matching the mesh's P ordering), so each
    mesh position owns exactly one ``(1, m)`` local/stale block and one
    overflow scalar inside shard_map.
    """
    row = (*batch_axes(mesh), CLAUSE_AXIS)
    return VoteAccumulator(local=P(row, None), stale=P(row, None),
                           overflow=P(row))


def vote_ranks(mesh) -> int:
    """R — total vote ranks (product of batch-axis sizes × clause shards)."""
    baxes = batch_axes(mesh)
    d = math.prod(mesh.shape[a] for a in baxes) if baxes else 1
    return d * clause_shards(mesh)


def init_vote_acc(cfg: TMConfig, mesh) -> VoteAccumulator:
    """Fresh all-zeros accumulator, placed per ``vote_acc_pspec``.

    Zeros are the correct cold start: a zero stale term makes the first
    window read pure local votes, and the first refresh replaces it with
    real sums. Explicit per-field device_put (PartitionSpec is a tuple
    subclass — tree-mapping over a spec tree would descend into it).
    """
    r, m = vote_ranks(mesh), cfg.n_classes
    spec = vote_acc_pspec(mesh)
    put = lambda arr, s: jax.device_put(arr, NamedSharding(mesh, s))  # noqa: E731
    return VoteAccumulator(
        local=put(jnp.zeros((r, m), jnp.int32), spec.local),
        stale=put(jnp.zeros((r, m), jnp.int32), spec.stale),
        overflow=put(jnp.zeros((r,), jnp.int32), spec.overflow))


def make_sharded_prepare(cfg: TMConfig, mesh, *, engines=None,
                         async_votes: int = 0):
    """``(TMState) -> TMBundle`` with shard-local caches for every engine.

    The state pads to the ragged clause layout and lands clause-sharded
    (``STATE_PSPEC``); each distinct cache slot is built *on its shard*
    from the local state slice — no device ever materialises a full cache.
    ``async_votes > 0`` additionally seeds the bundle's stale-vote
    accumulator (``init_vote_acc`` zeros — rebuildable state, never
    checkpointed).
    """
    geom = geometry(cfg, mesh)
    shards = geom.clause_shards
    keys = cache_keys_for(engines)
    state_sh = NamedSharding(mesh, STATE_PSPEC.ta_state)
    _, cache_specs = bundle_pspecs(cfg, engines)

    def local_fn(state_l: TMState):
        return {k: cache_provider(k).shard_prepare(cfg, state_l, shards)
                for k in keys}

    fn = jax.jit(shard_map_compat(local_fn, mesh=mesh,
                                  in_specs=(STATE_PSPEC,),
                                  out_specs=cache_specs))

    def prepare(state: TMState) -> TMBundle:
        state = pad_state(cfg, state, geom.n_padded)
        state = TMState(ta_state=jax.device_put(state.ta_state, state_sh))
        caches = fn(state) if keys else {}
        acc = init_vote_acc(cfg, mesh) if async_votes > 0 else None
        return TMBundle(cfg=cfg, state=state, caches=caches,
                        event_overflow=jnp.zeros((), jnp.int32),
                        vote_acc=acc)

    return prepare


def make_sharded_scores(cfg: TMConfig, mesh, *, engine: str = DEFAULT_ENGINE):
    """``(TMBundle, x) -> (B, m)`` scores through one engine, clause-sharded.

    Exactly one collective: the psum of per-shard partial votes (GSPMD
    lowers it to a single (B, m) all-reduce over ``CLAUSE_AXIS``). The batch
    shards over the data/pod axes communication-free. Clause-axis padding
    rows contribute zero partial votes (sign-0 polarity), so the reduced
    scores are the global Eq. 3/4 values for any ``(clause_shards,
    n_clauses)`` pair.
    """
    eng = get_engine(engine)
    baxes = batch_axes(mesh)
    bspec = P(baxes, None) if baxes else P(None, None)
    cache_spec = eng.cache_pspec(cfg)
    pol = _sharded_polarity(cfg, mesh)

    def local_fn(cache_l, pol_l, x_l):
        part = eng.partial_scores(cfg, cache_l, x_l, pol_l)
        return jax.lax.psum(part, CLAUSE_AXIS)

    fn = jax.jit(shard_map_compat(
        local_fn, mesh=mesh, in_specs=(cache_spec, P(CLAUSE_AXIS), bspec),
        out_specs=bspec))

    def operand(bundle: TMBundle):
        """The engine operand ``fn`` evaluates: the TA state for cache-less
        engines, the prepared shard-local cache otherwise."""
        if not eng.needs_cache:
            return bundle.state
        cache = bundle.caches.get(eng.cache_key)
        if cache is None:
            raise KeyError(
                f"engine {engine!r} (cache slot {eng.cache_key!r}) was not "
                f"prepared in this bundle (slots: {tuple(bundle.caches)}); "
                "include it in the engines= of make_sharded_prepare / the "
                "TMSession — sharded caches cannot be built on the fly")
        return cache

    def scores(bundle: TMBundle, x: jax.Array) -> jax.Array:
        return fn(operand(bundle), pol, x)

    def aot_jit(donate_x: bool = False):
        """The same shard_map body under an AOT-friendly ``jax.jit``:
        explicit per-operand in/out ``NamedSharding``s (so
        ``.lower(...).compile()`` bakes the placement into the executable
        instead of re-inferring it per call) and, when ``donate_x``, the
        batch operand donated — the serving AOT cache's lowering target
        (``TMSession.lower_scores`` / ``serving/aot.py``)."""
        as_named = lambda spec: jax.tree.map(  # noqa: E731
            lambda s: NamedSharding(mesh, s), spec,
            is_leaf=lambda s: isinstance(s, P))
        return jax.jit(
            shard_map_compat(local_fn, mesh=mesh,
                             in_specs=(cache_spec, P(CLAUSE_AXIS), bspec),
                             out_specs=bspec),
            in_shardings=(as_named(cache_spec), as_named(P(CLAUSE_AXIS)),
                          as_named(bspec)),
            out_shardings=as_named(bspec),
            donate_argnums=(2,) if donate_x else ())

    # exposed for the dry-run's HLO assertions (launch/dryrun.py --tm) and
    # the AOT serving cache's lowering hook (core/session.py lower_scores)
    scores.jitted, scores.pol, scores.engine = fn, pol, eng
    scores.operand, scores.aot_jit, scores.bspec = operand, aot_jit, bspec
    return scores


def make_sharded_train_step(cfg: TMConfig, mesh, *, engines=None,
                            parallel: bool = False, max_events: int = 4096,
                            donate: bool | None = None,
                            async_votes: int = 0):
    """``(TMBundle, xs, ys, rng[, mask]) -> TMBundle``, sharded end to end.

    Sequential mode keeps the paper's global sample order (online learning
    is sequential in samples by definition), so the data/pod axes cannot
    shard the *batch* — instead they compose with the clause axis
    **hierarchically**: each data rank scans the full batch over its own
    zero-padded clause *sub-slice* of ``⌈n_local/data_shards⌉`` rows
    (global clause order = model-major, data-minor), and one final psum
    over the data axes reassembles the model-shard slice. The vote psum
    inside ``tm._class_round`` then runs over *all* mesh axes — it already
    composed; the batch-order question is answered by giving the data axis
    clause work, not batch work. Padding rows (ragged sub-slices and the
    global clause-axis padding, DESIGN.md §9) carry sign-0 polarity and a
    zero update mask, so they are inert through the vote psum and frozen
    through the feedback kernels; sub-slice padding is dropped by the
    reassembly slice. Only when ``data_shards > n_local`` does the
    sequential step fall back to PR-2 batch replication (warned once,
    ``composition_rule='replicated'``). The batch-parallel approximation
    shards the batch over data/pod as before, psumming the summed TA
    deltas. Either way every collective is an all-reduce; the include-mask
    diff and every cache's event replay stay on the model shard
    (``max_events`` bounds the *per-shard* event buffer). Bit-exact with
    the single-device ``api.train_step`` (identical randomness via
    full-draw slicing).

    ``mask`` (B,) bool marks valid samples (the fixed-shape padding
    contract of ``api.train_step``); omitted → all rows valid. The fired
    composition rule is exposed as ``step.composition`` (and recorded by
    ``dryrun --tm`` / BENCH_tm_serve.json).

    ``async_votes > 0`` compiles the *asynchronous* step (DESIGN.md §11):
    every class round reads ``live local votes + bundle.vote_acc.stale``
    instead of psumming, so the step body contains **zero vote
    collectives** and no per-step overflow psum either (per-rank drop
    counts accumulate into the accumulator and ride the K-step refresh,
    ``make_vote_refresh``). The only collectives left are the ones state
    exactness genuinely requires: the reassembly psum under hierarchical
    composition, or the delta psum in batch-parallel mode — clause-only
    async training is collective-free. The step never refreshes the
    buffer itself; the session owns the K cadence.
    """
    geom = geometry(cfg, mesh)
    n_local = geom.n_local
    keys = cache_keys_for(engines)
    _, cache_specs = bundle_pspecs(cfg, engines)
    all_baxes = batch_axes(mesh)
    d_shards = geom.data_shards
    # sequential: hierarchical data×clause composition (even or ragged)
    compose = (not parallel) and geom.composes
    if (not parallel) and geom.composition == REPLICATED:
        warnings.warn(
            f"sequential sharded training fired composition rule "
            f"'{REPLICATED}': data_shards={d_shards} exceeds the per-shard "
            f"clause count n_local={n_local} (n_clauses={cfg.n_clauses} / "
            f"clause_shards={geom.clause_shards}), so there is no clause "
            "sub-slice to hand each data rank — the data axis replicates "
            "the batch instead of adding clause parallelism. Pick "
            "data_shards <= n_local to compose (rules "
            f"'{COMPOSED_EVEN}'/'{COMPOSED_RAGGED}', DESIGN.md §9).",
            RuntimeWarning, stacklevel=2)
    n_sub = geom.n_sub if compose else n_local
    n_sub_pad = geom.n_sub_padded if compose else n_local
    baxes = all_baxes if parallel else ()
    x_spec = P(baxes, None) if baxes else P(None, None)
    y_spec = P(baxes) if baxes else P(None)
    pol = _sharded_polarity(cfg, mesh)

    def local_fn(state_l: TMState, caches_l, pol_l, xs, ys, key_data, mask,
                 overflow_in):
        rng = jax.random.wrap_key_data(key_data)
        start = jax.lax.axis_index(CLAUSE_AXIS) * n_local
        old_inc = include_mask(cfg, state_l)
        # validity of this shard's local rows: only the trailing shard(s)
        # carry global clause-axis padding; None when the layout is exact
        # (keeps the even-geometry HLO identical to the pre-ragged path)
        local_valid = None
        if geom.ragged_clauses:
            local_valid = (start + jnp.arange(n_local)) < cfg.n_clauses
        if parallel:
            b_idx = jnp.int32(0)
            for a in baxes:
                b_idx = b_idx * mesh.shape[a] + jax.lax.axis_index(a)
            b_total = (xs.shape[0] * math.prod(mesh.shape[a] for a in baxes)
                       if baxes else None)
            new_state = tm.update_batch_parallel(
                cfg, state_l, xs, ys, rng, pol=pol_l, axis_name=CLAUSE_AXIS,
                clause_start=start, batch_axes=baxes,
                batch_start=b_idx * xs.shape[0], batch_total=b_total,
                mask=mask, clause_mask=local_valid)
        elif compose:
            # this data rank owns clause rows [d·n_sub, (d+1)·n_sub) of the
            # model shard's (sub-slice-padded) slice; votes psum over
            # (data axes + clause axis)
            d_idx = jnp.int32(0)
            for a in all_baxes:
                d_idx = d_idx * mesh.shape[a] + jax.lax.axis_index(a)
            off = d_idx * n_sub
            ta_pad = _pad_rows(state_l.ta_state, 1, n_sub_pad, cfg.n_states)
            pol_pad = _pad_rows(pol_l, 0, n_sub_pad, 0)
            sub = TMState(ta_state=jax.lax.dynamic_slice_in_dim(
                ta_pad, off, n_sub, 1))
            pol_sub = jax.lax.dynamic_slice_in_dim(pol_pad, off, n_sub, 0)
            sub_valid = None
            if geom.composition == COMPOSED_RAGGED or geom.ragged_clauses:
                rows = off + jnp.arange(n_sub)
                sub_valid = ((rows < n_local)
                             & ((start + rows) < cfg.n_clauses))
            new_sub = tm.update_batch_sequential(
                cfg, sub, xs, ys, rng, pol=pol_sub,
                axis_name=(*all_baxes, CLAUSE_AXIS),
                clause_start=start + off, mask=mask, clause_mask=sub_valid)
            # reassemble the model shard's slice: each real row is owned by
            # exactly one data rank, so a zero-padded psum is a gather
            # expressed as the one collective kind this step allows; the
            # trailing sub-slice padding rows land past n_local and are
            # dropped by the slice
            zeros = jnp.zeros(
                (state_l.ta_state.shape[0], n_sub_pad,
                 state_l.ta_state.shape[2]), state_l.ta_state.dtype)
            assembled = jax.lax.dynamic_update_slice_in_dim(
                zeros, new_sub.ta_state, off, 1)
            summed = jax.lax.psum(assembled, all_baxes)
            new_state = TMState(
                ta_state=jax.lax.slice_in_dim(summed, 0, n_local, axis=1))
        else:
            new_state = tm.update_batch_sequential(
                cfg, state_l, xs, ys, rng, pol=pol_l, axis_name=CLAUSE_AXIS,
                clause_start=start, mask=mask, clause_mask=local_valid)
        buf = indexing.events_from_transition(
            old_inc, include_mask(cfg, new_state), max_events)
        new_caches = {k: cache_provider(k).update_cache(
                          cfg, caches_l[k], new_state, buf.events)
                      for k in keys}
        # per-shard drop counts add over the clause axis (each model shard
        # diffs only its own include slice; data ranks see identical diffs),
        # yielding the replicated global overflow counter — an all-reduce,
        # never a gather, per the step's collective contract
        overflow = overflow_in + jax.lax.psum(buf.overflow, CLAUSE_AXIS)
        return new_state, new_caches, overflow

    def local_fn_async(state_l: TMState, caches_l, pol_l, acc_l, xs, ys,
                       key_data, mask):
        # Same shard-local structure as local_fn, with the vote psum (and
        # the per-step overflow psum) deleted: rounds read the accumulator's
        # stale remote term, vote/overflow stats land in the write buffer.
        rng = jax.random.wrap_key_data(key_data)
        start = jax.lax.axis_index(CLAUSE_AXIS) * n_local
        old_inc = include_mask(cfg, state_l)
        stale = acc_l.stale[0]  # (m,) — this rank's read buffer
        local_valid = None
        if geom.ragged_clauses:
            local_valid = (start + jnp.arange(n_local)) < cfg.n_clauses
        if parallel:
            b_idx = jnp.int32(0)
            for a in baxes:
                b_idx = b_idx * mesh.shape[a] + jax.lax.axis_index(a)
            b_total = (xs.shape[0] * math.prod(mesh.shape[a] for a in baxes)
                       if baxes else None)
            new_state, (vs, vc) = tm.update_batch_parallel(
                cfg, state_l, xs, ys, rng, pol=pol_l,
                clause_start=start, batch_axes=baxes,
                batch_start=b_idx * xs.shape[0], batch_total=b_total,
                mask=mask, clause_mask=local_valid, stale_votes=stale)
        elif compose:
            d_idx = jnp.int32(0)
            for a in all_baxes:
                d_idx = d_idx * mesh.shape[a] + jax.lax.axis_index(a)
            off = d_idx * n_sub
            ta_pad = _pad_rows(state_l.ta_state, 1, n_sub_pad, cfg.n_states)
            pol_pad = _pad_rows(pol_l, 0, n_sub_pad, 0)
            sub = TMState(ta_state=jax.lax.dynamic_slice_in_dim(
                ta_pad, off, n_sub, 1))
            pol_sub = jax.lax.dynamic_slice_in_dim(pol_pad, off, n_sub, 0)
            sub_valid = None
            if geom.composition == COMPOSED_RAGGED or geom.ragged_clauses:
                rows = off + jnp.arange(n_sub)
                sub_valid = ((rows < n_local)
                             & ((start + rows) < cfg.n_clauses))
            new_sub, (vs, vc) = tm.update_batch_sequential(
                cfg, sub, xs, ys, rng, pol=pol_sub,
                clause_start=start + off, mask=mask, clause_mask=sub_valid,
                stale_votes=stale)
            # the reassembly psum stays: state composition must be exact —
            # only the vote *feedback term* is allowed to go stale
            zeros = jnp.zeros(
                (state_l.ta_state.shape[0], n_sub_pad,
                 state_l.ta_state.shape[2]), state_l.ta_state.dtype)
            assembled = jax.lax.dynamic_update_slice_in_dim(
                zeros, new_sub.ta_state, off, 1)
            summed = jax.lax.psum(assembled, all_baxes)
            new_state = TMState(
                ta_state=jax.lax.slice_in_dim(summed, 0, n_local, axis=1))
        else:
            new_state, (vs, vc) = tm.update_batch_sequential(
                cfg, state_l, xs, ys, rng, pol=pol_l,
                clause_start=start, mask=mask, clause_mask=local_valid,
                stale_votes=stale)
        buf = indexing.events_from_transition(
            old_inc, include_mask(cfg, new_state), max_events)
        new_caches = {k: cache_provider(k).update_cache(
                          cfg, caches_l[k], new_state, buf.events)
                      for k in keys}
        # write buffer: batch-mean local partial votes per touched class
        # (untouched classes keep their previous estimate); overflow counts
        # accumulate per rank and drain at the next refresh collective
        new_local = jnp.where(
            vc > 0,
            jnp.round(vs / jnp.maximum(vc, 1)).astype(jnp.int32),
            acc_l.local[0])
        acc_out = VoteAccumulator(
            local=new_local[None], stale=acc_l.stale,
            overflow=acc_l.overflow + buf.overflow)
        return new_state, new_caches, acc_out

    mask_spec = y_spec  # batch-sharded in parallel mode, replicated otherwise
    if async_votes > 0:
        acc_spec = vote_acc_pspec(mesh)
        sm = shard_map_compat(
            local_fn_async, mesh=mesh,
            in_specs=(STATE_PSPEC, cache_specs, P(CLAUSE_AXIS), acc_spec,
                      x_spec, y_spec, P(None), mask_spec),
            out_specs=(STATE_PSPEC, cache_specs, acc_spec))
        donate_nums = (0, 1, 3) if resolve_donate(donate) else ()
        fn = jax.jit(sm, donate_argnums=donate_nums)

        def step(bundle: TMBundle, xs, ys, rng, mask=None) -> TMBundle:
            if bundle.vote_acc is None:
                raise ValueError(
                    "async_votes > 0 needs a bundle carrying a "
                    "VoteAccumulator — prepare it with "
                    "make_sharded_prepare(..., async_votes=K) (or let "
                    "TMSession.prepare do it)")
            if mask is None:
                mask = jnp.ones(xs.shape[0], bool)
            new_state, new_caches, acc = fn(
                bundle.state, bundle.caches, pol, bundle.vote_acc, xs, ys,
                jax.random.key_data(rng), mask)
            return TMBundle(cfg=cfg, state=new_state, caches=new_caches,
                            event_overflow=bundle.event_overflow,
                            vote_acc=acc)
    else:
        sm = shard_map_compat(
            local_fn, mesh=mesh,
            in_specs=(STATE_PSPEC, cache_specs, P(CLAUSE_AXIS), x_spec,
                      y_spec, P(None), mask_spec, P()),
            out_specs=(STATE_PSPEC, cache_specs, P()))
        donate_nums = (0, 1) if resolve_donate(donate) else ()
        fn = jax.jit(sm, donate_argnums=donate_nums)

        def step(bundle: TMBundle, xs, ys, rng, mask=None) -> TMBundle:
            if mask is None:
                mask = jnp.ones(xs.shape[0], bool)
            overflow_in = (bundle.event_overflow
                           if bundle.event_overflow is not None
                           else jnp.zeros((), jnp.int32))
            new_state, new_caches, overflow = fn(
                bundle.state, bundle.caches, pol, xs, ys,
                jax.random.key_data(rng), mask, overflow_in)
            return TMBundle(cfg=cfg, state=new_state, caches=new_caches,
                            event_overflow=overflow,
                            vote_acc=bundle.vote_acc)

    # exposed for the dry-run's HLO assertions (launch/dryrun.py --tm)
    step.jitted, step.pol = fn, pol
    step.geometry = geom
    step.composition = "batch_parallel" if parallel else geom.composition
    return step


def make_vote_refresh(cfg: TMConfig, mesh, *, parallel: bool = False,
                      donate: bool | None = None):
    """``(TMBundle) -> TMBundle`` — the K-step stale-vote refresh (§11).

    One batched all-reduce: each rank's ``(m,)`` local votes and its
    overflow scalar pack into a single ``(m+1,)`` psum. The vote axes match
    the async step's partitioning — every mesh axis under hierarchical
    composition (ranks own disjoint clause rows), the clause axis alone
    otherwise (data ranks replicate clause rows, so their totals already
    agree per rank) — and under composition only data-rank 0 contributes
    overflow (the ranks record identical drop counts for a clause shard;
    summing all of them would multiply-count by ``data_shards``).

    Out the other side: ``stale`` holds ``global − own local`` (the remote
    term the next window reads), per-rank overflow drains to zero, and the
    bundle's ``event_overflow`` absorbs the window's global drop count —
    the per-step overflow psum the sync path pays rides this collective
    instead. Exposes ``refresh.jitted`` for the dry-run's HLO assertions.
    """
    geom = geometry(cfg, mesh)
    all_baxes = batch_axes(mesh)
    compose = (not parallel) and geom.composes
    vote_axes = (*all_baxes, CLAUSE_AXIS) if compose else (CLAUSE_AXIS,)
    m = cfg.n_classes
    acc_spec = vote_acc_pspec(mesh)

    def local_fn(acc_l, overflow_in):
        local = acc_l.local[0]      # (m,)
        oflow = acc_l.overflow[0]   # ()
        if compose and all_baxes:
            d_idx = jnp.int32(0)
            for a in all_baxes:
                d_idx = d_idx * mesh.shape[a] + jax.lax.axis_index(a)
            oflow = jnp.where(d_idx == 0, oflow, 0)
        packed = jnp.concatenate([local, oflow[None].astype(jnp.int32)])
        total = jax.lax.psum(packed, vote_axes)  # THE one all-reduce per K
        stale = total[:m] - local
        acc_out = VoteAccumulator(
            local=acc_l.local, stale=stale[None],
            overflow=jnp.zeros_like(acc_l.overflow))
        return acc_out, overflow_in + total[m]

    sm = shard_map_compat(local_fn, mesh=mesh, in_specs=(acc_spec, P()),
                          out_specs=(acc_spec, P()))
    fn = jax.jit(sm, donate_argnums=(0,) if resolve_donate(donate) else ())

    def refresh(bundle: TMBundle) -> TMBundle:
        if bundle.vote_acc is None:
            raise ValueError("refresh needs a bundle with a VoteAccumulator")
        overflow_in = (bundle.event_overflow
                       if bundle.event_overflow is not None
                       else jnp.zeros((), jnp.int32))
        acc, overflow = fn(bundle.vote_acc, overflow_in)
        return TMBundle(cfg=cfg, state=bundle.state, caches=bundle.caches,
                        event_overflow=overflow, vote_acc=acc)

    refresh.jitted = fn
    return refresh


# The stateful facade over these factories is ``core/session.py``'s
# ``TMSession`` (``ShardedTM`` in PR 2): one session resolves a ``Topology``
# into either this shard_map path or the single-device jitted path, so
# callers never wire prepare/scores/train_step by hand.
