"""Evaluation-engine registry — one TM, many interchangeable eval strategies.

The paper's point is that a trained TM admits several semantically identical
evaluation strategies with very different work profiles (exhaustive vs the
falsification index, Gorji et al. 2020); the Massively Parallel TM line
(Abeyrathna et al. 2020) shows that decoupling clause *evaluation* from TA
*state storage* is what unlocks scaling. This module is that API boundary:

  * ``EvalEngine`` — ``prepare(cfg, state) -> cache`` builds the engine's
    pytree cache (packed include words, ``CompactClauses``, ``ClauseIndex``);
    ``scores(cfg, cache, x)`` evaluates from the cache alone;
    ``update_cache(cfg, cache, state, events)`` absorbs include/exclude
    boundary crossings *incrementally* so learning never rebuilds or
    host-syncs a cache per step.
  * ``register_engine`` / ``get_engine`` / ``registered_engines`` — the
    registry. ``dense``, ``bitpack``, ``bitpack_xla``, ``compact`` and
    ``indexed`` register at import; new engines (sharded, weighted, …)
    plug in without touching the estimator, the shim, the parity tests or
    the benchmarks — all of which iterate the registry. Kernel-vs-XLA
    *bodies* are no longer an engine property: the packed engine resolves
    its evaluation through the kernel backend registry
    (``kernels/backend.py``, selected by ``cfg.backend``), and
    ``bitpack_xla`` is just ``bitpack`` pinned to ``backend='xla'``.

Engines that derive the *same* cache share it via ``cache_key`` (``bitpack``
and ``bitpack_xla`` both read the packed include words), so a ``TMBundle``
stores and maintains each distinct cache once.

Every method is pure and jit-compatible: cache shapes are static functions
of ``TMConfig`` (``resolved_index_capacity`` / ``resolved_clause_capacity``),
never of the data — the seed's ``np.asarray(include_mask(...)).max()`` host
round-trip at inference time is gone.

Score semantics: all engines implement the paper's Eq. 4 convention (empty /
never-falsified clauses count as true). With ``cfg.empty_clause_output == 1``
(the default) every engine returns *identical* scores; with 0 only ``dense``
follows the classic convention and the others still agree on ``argmax`` in
the usual case (tests pin the score identity in paper mode).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core import indexing, tm
from repro.core.bitpack import WORD, pack_bits, packed_literals
from repro.core.indexing import Event
from repro.core.types import (
    TMConfig, TMState, clause_polarity, include_mask, literals_from_input)
from repro.kernels import backend as kbackend

# Mesh axis name the clause dimension shards over (production meshes call
# their tensor axis "model"; clauses are the TM's model dimension).
CLAUSE_AXIS = "model"


class EvalEngine:
    """Base class for evaluation engines. Subclass + ``register_engine``.

    ``name``        — registry key, the user-facing engine string.
    ``cache_key``   — storage key inside a ``TMBundle``; engines with the same
                      ``cache_key`` must build byte-identical caches (they are
                      prepared and maintained once, by the first registrant).
    ``needs_cache`` — False when ``prepare`` is the identity over state the
                      bundle already carries; such engines never store a cache
                      (storing one would alias ``state``'s buffers inside the
                      same pytree, which breaks donation — a donated bundle
                      must not donate one buffer through two leaves).

    Shard contract (core/distributed.py): an engine that supports clause
    sharding declares ``cache_pspec`` (how its cache pytree partitions over
    ``CLAUSE_AXIS``), builds its shard-local cache from a clause shard of the
    state via ``shard_prepare``, and evaluates partial votes via
    ``partial_scores``. ``update_cache`` is *already* shard-local: Type I/II
    feedback is clause-local given the vote, so each shard replays only its
    own events against its own cache — no extra methods needed for learning.
    """

    name: str = ""
    cache_key: str = ""
    needs_cache: bool = True

    def prepare(self, cfg: TMConfig, state: TMState):
        """Build this engine's cache pytree from scratch (pure, jittable)."""
        raise NotImplementedError

    def scores(self, cfg: TMConfig, cache, x: jax.Array) -> jax.Array:
        """(B, o) inputs → (B, m) class scores from the cache alone."""
        raise NotImplementedError

    def update_cache(self, cfg: TMConfig, cache, state: TMState,
                     events: Event):
        """Absorb TA boundary crossings; default falls back to a rebuild.

        ``state`` is the *post*-update TA state; ``events`` the include-mask
        diff that produced it (``indexing.events_from_transition``). Caches
        must have been in sync with the pre-update state — the TMBundle sync
        contract (DESIGN.md §3).
        """
        del events
        return self.prepare(cfg, state)

    # -- shard contract (DESIGN.md §6) --------------------------------------

    def cache_pspec(self, cfg: TMConfig):
        """PartitionSpec pytree (same structure as the cache) placing the
        clause axis on ``CLAUSE_AXIS``. Axes whose *values* are shard-local
        (list slots, per-shard counts) tile over ``CLAUSE_AXIS`` as opaque
        blocks — the assembled global array is storage, interpreted only
        through shard_map with this same spec."""
        raise NotImplementedError(
            f"engine {self.name!r} does not declare a cache PartitionSpec; "
            "implement cache_pspec/shard_prepare/partial_scores to make it "
            "clause-shardable (DESIGN.md §6)")

    def shard_prepare(self, cfg: TMConfig, state: TMState, n_shards: int):
        """Shard-local cache from a clause shard of the state. Default:
        ``prepare`` — correct whenever cache shapes carry the clause axis
        directly (the indexed engine overrides to split list capacity)."""
        del n_shards
        return self.prepare(cfg, state)

    def partial_scores(self, cfg: TMConfig, cache, x: jax.Array,
                       pol: jax.Array) -> jax.Array:
        """(B, m) partial vote sums over this shard's clauses.

        ``pol`` is the shard's ±1 polarity slice; partials must *add* across
        shards — one psum over ``CLAUSE_AXIS`` yields the engine's global
        scores (the single (B, m) vote all-reduce).
        """
        raise NotImplementedError(
            f"engine {self.name!r} does not implement partial_scores")


def _partial_votes(clause_out: jax.Array, pol: jax.Array) -> jax.Array:
    """(B, m, n_local) clause outputs × (n_local,) ±1 polarity → (B, m)."""
    return jnp.einsum("bmn,n->bm", clause_out.astype(jnp.int32),
                      pol.astype(jnp.int32))


_REGISTRY: dict[str, EvalEngine] = {}
_CACHE_PROVIDERS: dict[str, EvalEngine] = {}


def register_engine(engine: EvalEngine) -> EvalEngine:
    """Add an engine instance to the registry (idempotent per name)."""
    if not engine.name:
        raise ValueError("engine must set a non-empty .name")
    if not engine.cache_key:
        engine.cache_key = engine.name
    _REGISTRY[engine.name] = engine
    # first registrant for a cache_key owns prepare/update for it
    _CACHE_PROVIDERS.setdefault(engine.cache_key, engine)
    return engine


def get_engine(name: str) -> EvalEngine:
    """Look up a registered engine by name (KeyError lists what exists)."""
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(
            f"unknown engine {name!r}; registered: {registered_engines()}"
        ) from None


def registered_engines() -> tuple[str, ...]:
    """Registered engine names, registration order."""
    return tuple(_REGISTRY)


def cache_provider(cache_key: str) -> EvalEngine:
    """The engine that owns prepare/update for a given cache slot."""
    return _CACHE_PROVIDERS[cache_key]


# ---------------------------------------------------------------------------
# dense — exhaustive evaluation (the paper's baseline)
# ---------------------------------------------------------------------------


class DenseEngine(EvalEngine):
    """Exhaustive eval straight off the TA state; the cache *is* the state,
    so no cache is ever stored (``needs_cache=False``) — ``bundle_scores``
    falls through to the zero-cost ``prepare``."""

    name = "dense"
    needs_cache = False

    def prepare(self, cfg: TMConfig, state: TMState) -> TMState:
        return state

    def scores(self, cfg: TMConfig, cache: TMState, x: jax.Array) -> jax.Array:
        return tm.scores(cfg, cache, x)

    def update_cache(self, cfg, cache, state, events):
        del events
        return state  # zero-copy: the new state is the new cache

    def cache_pspec(self, cfg):
        # the "cache" is the TA state itself: (m, n, 2o) over clauses
        return TMState(ta_state=P(None, CLAUSE_AXIS, None))

    def partial_scores(self, cfg, cache, x, pol):
        return _partial_votes(tm.dense_clause_outputs(cfg, cache, x), pol)


# ---------------------------------------------------------------------------
# bitpack / bitpack_xla — 32×-packed include words (shared cache)
# ---------------------------------------------------------------------------


def packed_include_apply_events(words: jax.Array, events: Event) -> jax.Array:
    """Flip include bits for a masked event buffer, one scatter-add.

    Events from ``events_from_transition`` touch *distinct* (i, j, k) cells
    and always cross the boundary in the stated direction (insert: bit is 0,
    delete: bit is 1), so per-word bit deltas sum without carries and the
    whole buffer lands in a single vectorised scatter — no scan.
    """
    word = events.literal // WORD
    bit = (events.literal % WORD).astype(jnp.uint32)
    mask = (jnp.uint32(1) << bit).astype(jnp.uint32)
    sign = jnp.where(events.is_insert, jnp.uint32(1), jnp.uint32(0xFFFFFFFF))
    delta = jnp.where(events.valid, mask * sign, jnp.uint32(0))
    return words.at[events.cls, events.clause, word].add(delta, mode="drop")


class BitpackEngine(EvalEngine):
    """32×-packed include words, evaluated through the kernel backend
    registry (``kernels/backend.py``): the ``clause_votes`` primitive
    resolves ``cfg.backend`` into the fused Pallas eval+vote kernel or its
    XLA reference body — the same resolution single-device and as the
    shard-local evaluator under shard_map (the kernel takes the shard's
    local ±1 polarity slice; partial votes add across shards, one psum).

    ``bitpack_xla`` is a registry *alias*: the same engine pinned to
    ``backend='xla'`` regardless of the config (it shares the ``bitpack``
    cache slot, so a bundle maintains the packed words once).
    """

    cache_key = "bitpack"
    name = "bitpack"

    def __init__(self, name: str | None = None,
                 backend: str | None = None):
        if name is not None:
            self.name = name
        self.backend = backend  # None → resolve cfg.backend

    def _votes(self, cfg: TMConfig):
        return kbackend.resolve("clause_votes", self.backend or cfg.backend)

    def prepare(self, cfg: TMConfig, state: TMState) -> jax.Array:
        return pack_bits(include_mask(cfg, state).astype(jnp.uint8))

    def update_cache(self, cfg, cache, state, events):
        del state
        return packed_include_apply_events(cache, events)

    def cache_pspec(self, cfg):
        return P(None, CLAUSE_AXIS, None)                     # (m, n, W)

    def scores(self, cfg, cache, x):
        return self._votes(cfg)(cache, packed_literals(x),
                                clause_polarity(cfg))

    def partial_scores(self, cfg, cache, x, pol):
        return self._votes(cfg)(cache, packed_literals(x), pol)


# ---------------------------------------------------------------------------
# compact — gather over included literals (work ∝ Σ clause lengths)
# ---------------------------------------------------------------------------


class CompactEngine(EvalEngine):
    """Clause-compact transpose layout; ℓ_max is static from the config
    (``cfg.resolved_clause_capacity``), not a data-dependent host sync."""

    name = "compact"

    def prepare(self, cfg: TMConfig, state: TMState) -> indexing.CompactClauses:
        return indexing.compact(cfg, state, cfg.resolved_clause_capacity)

    def scores(self, cfg, cache, x):
        return indexing.compact_scores(cfg, cache, x)

    def update_cache(self, cfg, cache, state, events):
        del state
        return indexing.compact_apply_events(cache, events)

    def cache_pspec(self, cfg):
        return indexing.CompactClauses(
            lit_idx=P(None, CLAUSE_AXIS, None),               # (m, n, ℓ_max)
            lengths=P(None, CLAUSE_AXIS))                     # (m, n)

    def partial_scores(self, cfg, cache, x, pol):
        return _partial_votes(indexing.compact_eval(cfg, cache, x), pol)


# ---------------------------------------------------------------------------
# indexed — the paper's falsification index (Eq. 4)
# ---------------------------------------------------------------------------


class IndexedEngine(EvalEngine):
    """Inclusion lists + batched O(events) maintenance (paper §3).

    Both hot paths resolve through the kernel backend registry: scoring is
    the matmul-form Eq. 4 over the position matrix's membership mask
    (``indexed_votes`` — XLA GEMM body or the fused Pallas kernel per
    ``cfg.backend``), maintenance the batched event replay
    (``index_update``). The sequential ``indexing.apply_events`` scan stays
    as the semantics oracle, not the production route.
    """

    name = "indexed"

    def _votes(self, cfg: TMConfig):
        return kbackend.resolve("indexed_votes", cfg.backend)

    def prepare(self, cfg: TMConfig, state: TMState) -> indexing.ClauseIndex:
        return indexing.build_index(cfg, state, cfg.resolved_index_capacity)

    def scores(self, cfg, cache, x):
        return self._votes(cfg)(cache.pos, literals_from_input(x),
                                clause_polarity(cfg))

    def update_cache(self, cfg, cache, state, events):
        del state
        return indexing.index_update(cache, events, backend=cfg.backend)

    def cache_pspec(self, cfg):
        # Per-shard falsification lists: each shard owns complete lists over
        # *its own* clauses (local ids), so the falsified-union is shard-local
        # and partial counts add. lists tile capacity rows, counts tile their
        # per-shard (m, 2o) blocks — opaque storage outside shard_map.
        return indexing.ClauseIndex(
            lists=P(None, None, CLAUSE_AXIS),                 # (m, 2o, cap)
            counts=P(None, CLAUSE_AXIS),                      # (m, S·2o)
            pos=P(None, CLAUSE_AXIS, None))                   # (m, n, 2o)

    def shard_prepare(self, cfg, state, n_shards):
        cap = indexing.shard_capacity(cfg.resolved_index_capacity, n_shards)
        return indexing.build_index(cfg, state, cap)

    def partial_scores(self, cfg, cache, x, pol):
        return self._votes(cfg)(cache.pos, literals_from_input(x), pol)


register_engine(DenseEngine())
register_engine(BitpackEngine())
# registry alias: same engine + cache, backend pinned to the XLA body
register_engine(BitpackEngine(name="bitpack_xla", backend="xla"))
register_engine(CompactEngine())
register_engine(IndexedEngine())
