"""Jit-native TM estimator API: ``TMBundle`` + ``TsetlinMachine``.

Layering (DESIGN.md):

  * ``TMBundle`` — a registered pytree bundling the static ``TMConfig``
    (treedef aux data, so jit re-traces per config, never per state) with the
    learnable ``TMState`` and the per-``cache_key`` engine caches. One value
    carries everything needed to train *and* serve through any engine.
  * ``train_step(bundle, xs, ys, rng) -> bundle`` — a pure function: dense
    TA feedback, include-mask diff into a fixed-shape event buffer, then
    every cache in the bundle absorbs the events incrementally through its
    registry provider. ``jax.jit``s end-to-end; no Python-level mutation, no
    host sync inside the step. ``train_step_jit`` donates the input bundle
    (on backends that support donation) so TA states update in place.
The estimator facade (``TsetlinMachine``) and the topology resolution layer
(``Topology`` / ``TMSession``) live in ``core/session.py``; this module is
the pure single-device substrate both paths share.
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import Any, Iterable

import jax
import jax.numpy as jnp

from repro.core import indexing, tm
from repro.core.engines import cache_provider, get_engine, registered_engines
from repro.core.types import TMConfig, TMState, include_mask, init_tm

DEFAULT_ENGINE = "indexed"


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class TMBundle:
    """Static config + TA state + engine caches, as one jit-friendly pytree.

    ``event_overflow`` is the cumulative count of cache-sync events dropped
    by the fixed-shape buffer since the bundle was prepared (None before any
    training). It stays on device — reading it costs one scalar transfer —
    and non-zero means the caches are stale: raise ``max_events`` instead of
    sizing it to the worst case blindly (``indexing.EventBuffer``). The
    buffer is per clause shard, so the threshold the counter reflects scales
    with ``clause_shards`` — size ``max_events`` for the least-sharded
    placement a state will run on.

    ``vote_acc`` is the double-buffered stale-vote accumulator
    (``types.VoteAccumulator``) carried only when a sharded topology trains
    with ``async_votes=K>0`` (DESIGN.md §11); None everywhere else. It is
    rebuildable state — checkpoints never persist it.
    """

    cfg: TMConfig
    state: TMState
    caches: dict[str, Any]
    event_overflow: jax.Array | None = None
    vote_acc: Any = None

    def tree_flatten(self):
        """Pytree protocol: leaves = (state, caches, overflow, vote_acc),
        aux = cfg."""
        return ((self.state, self.caches, self.event_overflow, self.vote_acc),
                self.cfg)

    @classmethod
    def tree_unflatten(cls, cfg, children):
        """Pytree protocol: rebuild from ``tree_flatten``'s output."""
        state, caches, event_overflow, vote_acc = children
        return cls(cfg=cfg, state=state, caches=caches,
                   event_overflow=event_overflow, vote_acc=vote_acc)

    @property
    def index(self) -> indexing.ClauseIndex:
        """The paper's clause index (present when the indexed engine is on)."""
        return self.caches["indexed"]


def cache_keys_for(engine_names: Iterable[str] | None = None) -> tuple[str, ...]:
    """Distinct cache slots the named engines need (``None`` → all registered).

    Cache-less engines (``needs_cache=False``) read ``bundle.state`` directly
    and contribute no slot. Public because the sharded layer
    (``core/distributed.py``) builds shard-local caches for the same slots.
    """
    names = (tuple(engine_names) if engine_names is not None
             else registered_engines())
    keys: dict[str, None] = {}
    for name in names:
        eng = get_engine(name)
        if eng.needs_cache:  # cache-less engines read bundle.state directly
            keys.setdefault(eng.cache_key, None)
    return tuple(keys)


def init_bundle(
    cfg: TMConfig,
    *,
    engines: Iterable[str] | None = None,
    state: TMState | None = None,
    rng: jax.Array | None = None,
) -> TMBundle:
    """Fresh bundle with caches prepared for the requested engines.

    ``engines=None`` prepares every registered engine — each *distinct*
    ``cache_key`` is built once (``bitpack``/``bitpack_xla`` share).
    """
    names = tuple(engines) if engines is not None else registered_engines()
    state = state if state is not None else init_tm(cfg, rng)
    caches = {key: cache_provider(key).prepare(cfg, state)
              for key in cache_keys_for(names)}
    return TMBundle(cfg=cfg, state=state, caches=caches,
                    event_overflow=jnp.zeros((), jnp.int32))


# cache_keys whose on-the-fly rebuild has already been warned about once —
# a missing slot silently rebuilding per call is a config smell (the engine
# should be in the bundle's engines=), but it is not an error.
_REBUILD_WARNED: set[str] = set()


def bundle_scores(
    bundle: TMBundle, x: jax.Array, *, engine: str = DEFAULT_ENGINE
) -> jax.Array:
    """(B, o) → (B, m) scores via a registered engine (pure, jittable).

    Uses the bundle's maintained cache when present; otherwise prepares one
    on the fly (still pure — just does rebuild work per call, and warns once
    per cache slot so the rebuild cost never hides in a serving loop).
    """
    eng = get_engine(engine)
    cache = bundle.caches.get(eng.cache_key)
    if cache is None:
        if eng.needs_cache and eng.cache_key not in _REBUILD_WARNED:
            _REBUILD_WARNED.add(eng.cache_key)
            warnings.warn(
                f"bundle_scores(engine={engine!r}): cache slot "
                f"{eng.cache_key!r} is not maintained in this bundle "
                f"(slots: {tuple(bundle.caches)}); rebuilding it on every "
                "call — include the engine in the bundle's engines= to "
                "maintain it incrementally (warned once per slot)",
                RuntimeWarning, stacklevel=2)
        cache = eng.prepare(bundle.cfg, bundle.state)
    return eng.scores(bundle.cfg, cache, x)


def bundle_predict(
    bundle: TMBundle, x: jax.Array, *, engine: str = DEFAULT_ENGINE
) -> jax.Array:
    """(B, o) → (B,) argmax class via a registered engine (pure, jittable)."""
    return jnp.argmax(bundle_scores(bundle, x, engine=engine), axis=-1)


def sync_caches(bundle: TMBundle, new_state: TMState,
                buf: indexing.EventBuffer) -> TMBundle:
    """New bundle whose caches absorbed the buffer's events via their
    providers; the bundle's overflow counter accumulates the buffer's."""
    caches = {key: cache_provider(key).update_cache(
                  bundle.cfg, cache, new_state, buf.events)
              for key, cache in bundle.caches.items()}
    overflow = buf.overflow
    if bundle.event_overflow is not None:
        overflow = overflow + bundle.event_overflow
    return TMBundle(cfg=bundle.cfg, state=new_state, caches=caches,
                    event_overflow=overflow, vote_acc=bundle.vote_acc)


def train_step(
    bundle: TMBundle,
    xs: jax.Array,
    ys: jax.Array,
    rng: jax.Array,
    mask: jax.Array | None = None,
    *,
    parallel: bool = False,
    max_events: int = 4096,
) -> TMBundle:
    """One learning step over a batch; every engine cache stays in sync.

    Pure function of its inputs: dense Type I/II feedback (sequential scan,
    or the batch-parallel approximation when ``parallel=True``), then the
    include-mask diff replays into each cache as a fixed-shape masked event
    buffer (≤ ``max_events`` boundary crossings per batch — overflow drops
    events and is a config error). Dropped events are *counted* into the
    returned bundle's ``event_overflow``, so callers size ``max_events`` to
    the expected load and assert the counter stays 0 instead of paying the
    ``n_classes · n_clauses · n_literals`` worst case up front (cf. the
    examples).

    ``mask`` (B,) bool marks valid samples: padded rows consume their
    per-sample randomness but apply no update, so a trailing partial batch
    can pad to the compiled shape without a recompile and without training
    on garbage (the ``TsetlinMachine.fit`` padding contract).
    """
    cfg = bundle.cfg
    old_inc = include_mask(cfg, bundle.state)
    update = (tm.update_batch_parallel if parallel
              else tm.update_batch_sequential)
    new_state = update(cfg, bundle.state, xs, ys, rng, mask=mask)
    buf = indexing.events_from_transition(
        old_inc, include_mask(cfg, new_state), max_events)
    return sync_caches(bundle, new_state, buf)


# Donation updates TA states/caches in place on accelerators; the CPU backend
# does not implement buffer donation (XLA warns and copies). The decision is
# made lazily per donate flag at first call — resolving it at import time
# would both force backend initialization as an import side effect and freeze
# the choice before the program can configure its platform. Keyed by the
# resolved donate flag so ``Topology(donate=...)`` overrides share the cache.
_TRAIN_STEP_JIT: dict[bool, Any] = {}


def resolve_donate(donate: bool | None) -> bool:
    """``None`` → donate wherever the backend implements it (not CPU)."""
    return jax.default_backend() != "cpu" if donate is None else donate


def train_step_jit(bundle, xs, ys, rng, mask=None, *, parallel: bool = False,
                   max_events: int = 4096, donate: bool | None = None):
    """``train_step`` under ``jax.jit``, donating the input bundle on
    backends that implement donation (or per the explicit ``donate``
    override). NOTE: where donation applies (GPU/TPU), the input bundle's
    buffers are consumed — do not read it after the call; use the pure
    ``train_step`` if you need both."""
    donate = resolve_donate(donate)
    fn = _TRAIN_STEP_JIT.get(donate)
    if fn is None:
        fn = jax.jit(train_step, static_argnames=("parallel", "max_events"),
                     donate_argnums=(0,) if donate else ())
        _TRAIN_STEP_JIT[donate] = fn
    return fn(bundle, xs, ys, rng, mask, parallel=parallel,
              max_events=max_events)


# module-level so the XLA compilation cache is shared across sessions and
# estimator instances (a freshly loaded machine reuses the compiled graphs)
_scores_jit = jax.jit(bundle_scores, static_argnames=("engine",))
_predict_jit = jax.jit(bundle_predict, static_argnames=("engine",))
