"""Jit-native TM estimator API: ``TMBundle`` + ``TsetlinMachine``.

Layering (DESIGN.md):

  * ``TMBundle`` — a registered pytree bundling the static ``TMConfig``
    (treedef aux data, so jit re-traces per config, never per state) with the
    learnable ``TMState`` and the per-``cache_key`` engine caches. One value
    carries everything needed to train *and* serve through any engine.
  * ``train_step(bundle, xs, ys, rng) -> bundle`` — a pure function: dense
    TA feedback, include-mask diff into a fixed-shape event buffer, then
    every cache in the bundle absorbs the events incrementally through its
    registry provider. ``jax.jit``s end-to-end; no Python-level mutation, no
    host sync inside the step. ``train_step_jit`` donates the input bundle
    (on backends that support donation) so TA states update in place.
  * ``TsetlinMachine`` — a thin stateful facade (init / fit / partial_fit /
    predict / scores / evaluate) for scripts and examples; all real work is
    in the pure functions, which distributed/serving code calls directly.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Iterable

import jax
import jax.numpy as jnp

from repro.core import indexing, tm
from repro.core.engines import cache_provider, get_engine, registered_engines
from repro.core.types import TMConfig, TMState, include_mask, init_tm

DEFAULT_ENGINE = "indexed"


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class TMBundle:
    """Static config + TA state + engine caches, as one jit-friendly pytree."""

    cfg: TMConfig
    state: TMState
    caches: dict[str, Any]

    def tree_flatten(self):
        return (self.state, self.caches), self.cfg

    @classmethod
    def tree_unflatten(cls, cfg, children):
        state, caches = children
        return cls(cfg=cfg, state=state, caches=caches)

    @property
    def index(self) -> indexing.ClauseIndex:
        """The paper's clause index (present when the indexed engine is on)."""
        return self.caches["indexed"]


def cache_keys_for(engine_names: Iterable[str] | None = None) -> tuple[str, ...]:
    """Distinct cache slots the named engines need (``None`` → all registered).

    Cache-less engines (``needs_cache=False``) read ``bundle.state`` directly
    and contribute no slot. Public because the sharded layer
    (``core/distributed.py``) builds shard-local caches for the same slots.
    """
    names = (tuple(engine_names) if engine_names is not None
             else registered_engines())
    keys: dict[str, None] = {}
    for name in names:
        eng = get_engine(name)
        if eng.needs_cache:  # cache-less engines read bundle.state directly
            keys.setdefault(eng.cache_key, None)
    return tuple(keys)


def init_bundle(
    cfg: TMConfig,
    *,
    engines: Iterable[str] | None = None,
    state: TMState | None = None,
    rng: jax.Array | None = None,
) -> TMBundle:
    """Fresh bundle with caches prepared for the requested engines.

    ``engines=None`` prepares every registered engine — each *distinct*
    ``cache_key`` is built once (``bitpack``/``bitpack_xla`` share).
    """
    names = tuple(engines) if engines is not None else registered_engines()
    state = state if state is not None else init_tm(cfg, rng)
    caches = {key: cache_provider(key).prepare(cfg, state)
              for key in cache_keys_for(names)}
    return TMBundle(cfg=cfg, state=state, caches=caches)


def bundle_scores(
    bundle: TMBundle, x: jax.Array, *, engine: str = DEFAULT_ENGINE
) -> jax.Array:
    """(B, o) → (B, m) scores via a registered engine (pure, jittable).

    Uses the bundle's maintained cache when present; otherwise prepares one
    on the fly (still pure — just does rebuild work per call).
    """
    eng = get_engine(engine)
    cache = bundle.caches.get(eng.cache_key)
    if cache is None:
        cache = eng.prepare(bundle.cfg, bundle.state)
    return eng.scores(bundle.cfg, cache, x)


def bundle_predict(
    bundle: TMBundle, x: jax.Array, *, engine: str = DEFAULT_ENGINE
) -> jax.Array:
    return jnp.argmax(bundle_scores(bundle, x, engine=engine), axis=-1)


def sync_caches(bundle: TMBundle, new_state: TMState,
                events: indexing.Event) -> TMBundle:
    """New bundle whose caches absorbed ``events`` via their providers."""
    caches = {key: cache_provider(key).update_cache(
                  bundle.cfg, cache, new_state, events)
              for key, cache in bundle.caches.items()}
    return TMBundle(cfg=bundle.cfg, state=new_state, caches=caches)


def train_step(
    bundle: TMBundle,
    xs: jax.Array,
    ys: jax.Array,
    rng: jax.Array,
    *,
    parallel: bool = False,
    max_events: int = 4096,
) -> TMBundle:
    """One learning step over a batch; every engine cache stays in sync.

    Pure function of its inputs: dense Type I/II feedback (sequential scan,
    or the batch-parallel approximation when ``parallel=True``), then the
    include-mask diff replays into each cache as a fixed-shape masked event
    buffer (≤ ``max_events`` boundary crossings per batch — overflow drops
    events and is a config error; size it like the seed driver did).
    """
    cfg = bundle.cfg
    old_inc = include_mask(cfg, bundle.state)
    update = (tm.update_batch_parallel if parallel
              else tm.update_batch_sequential)
    new_state = update(cfg, bundle.state, xs, ys, rng)
    events = indexing.events_from_transition(
        old_inc, include_mask(cfg, new_state), max_events)
    return sync_caches(bundle, new_state, events)


# Donation updates TA states/caches in place on accelerators; the CPU backend
# does not implement buffer donation (XLA warns and copies). The decision is
# made lazily per backend at first call — resolving it at import time would
# both force backend initialization as an import side effect and freeze the
# choice before the program can configure its platform.
_TRAIN_STEP_JIT: dict[str, Any] = {}


def train_step_jit(bundle, xs, ys, rng, *, parallel: bool = False,
                   max_events: int = 4096):
    """``train_step`` under ``jax.jit``, donating the input bundle on
    backends that implement donation. NOTE: where donation applies
    (GPU/TPU), the input bundle's buffers are consumed — do not read it
    after the call; use the pure ``train_step`` if you need both."""
    backend = jax.default_backend()
    fn = _TRAIN_STEP_JIT.get(backend)
    if fn is None:
        fn = jax.jit(train_step, static_argnames=("parallel", "max_events"),
                     donate_argnums=(0,) if backend != "cpu" else ())
        _TRAIN_STEP_JIT[backend] = fn
    return fn(bundle, xs, ys, rng, parallel=parallel, max_events=max_events)


# module-level so the XLA compilation cache is shared across estimator
# instances (a fresh load_pytree'd machine reuses the compiled graphs)
_scores_jit = jax.jit(bundle_scores, static_argnames=("engine",))


class TsetlinMachine:
    """Estimator facade over the pure bundle functions.

    >>> machine = TsetlinMachine(cfg).init()
    >>> machine.fit(xs, ys, epochs=3)
    >>> machine.predict(x_test, engine="indexed")

    Every heavy call delegates to jitted pure functions of the bundle; the
    facade only owns the bundle reference and the RNG chain.
    """

    def __init__(
        self,
        cfg: TMConfig,
        *,
        engines: Iterable[str] | None = None,
        parallel: bool = False,
        max_events_per_batch: int = 4096,
        seed: int = 0,
    ):
        self.cfg = cfg
        self.engines = (tuple(engines) if engines is not None
                        else registered_engines())
        self.parallel = parallel
        self.max_events_per_batch = max_events_per_batch
        self._key = jax.random.key(seed)
        self.bundle: TMBundle | None = None

    # -- lifecycle ----------------------------------------------------------

    def init(self, rng: jax.Array | None = None) -> "TsetlinMachine":
        self.bundle = init_bundle(self.cfg, engines=self.engines, rng=rng)
        return self

    def _ensure_bundle(self) -> TMBundle:
        if self.bundle is None:
            self.init()
        return self.bundle

    def _next_key(self, rng: jax.Array | None) -> jax.Array:
        if rng is not None:
            return rng
        self._key, sub = jax.random.split(self._key)
        return sub

    # -- learning -----------------------------------------------------------

    def partial_fit(self, xs, ys, rng: jax.Array | None = None) -> "TsetlinMachine":
        """One jitted train step over a batch (all engine caches kept in sync)."""
        bundle = self._ensure_bundle()
        self.bundle = train_step_jit(
            bundle, xs, ys, self._next_key(rng),
            parallel=self.parallel, max_events=self.max_events_per_batch)
        return self

    def fit(self, xs, ys, *, epochs: int = 1, batch_size: int | None = None,
            rng: jax.Array | None = None) -> "TsetlinMachine":
        """Epoch loop of ``partial_fit``; fixed-size minibatches when
        ``batch_size`` is set (a trailing partial batch is dropped so every
        step reuses one compiled shape)."""
        if batch_size is not None and xs.shape[0] < batch_size:
            raise ValueError(
                f"batch_size={batch_size} exceeds dataset size "
                f"{xs.shape[0]}: fit would perform zero steps")
        key = self._next_key(rng)
        for _ in range(epochs):
            if batch_size is None:
                key, sub = jax.random.split(key)
                self.partial_fit(xs, ys, sub)
            else:
                for start in range(0, xs.shape[0] - batch_size + 1, batch_size):
                    key, sub = jax.random.split(key)
                    self.partial_fit(xs[start:start + batch_size],
                                     ys[start:start + batch_size], sub)
        return self

    # -- inference ----------------------------------------------------------

    def scores(self, xs, *, engine: str = DEFAULT_ENGINE) -> jax.Array:
        return _scores_jit(self._ensure_bundle(), xs, engine=engine)

    def predict(self, xs, *, engine: str = DEFAULT_ENGINE) -> jax.Array:
        return jnp.argmax(self.scores(xs, engine=engine), axis=-1)

    def evaluate(self, xs, ys, *, engine: str = DEFAULT_ENGINE) -> float:
        return float(jnp.mean(
            (self.predict(xs, engine=engine) == ys).astype(jnp.float32)))

    # -- state access / persistence -----------------------------------------

    @property
    def state(self) -> TMState:
        return self._ensure_bundle().state

    @property
    def index(self) -> indexing.ClauseIndex:
        return self._ensure_bundle().index

    def as_pytree(self) -> dict:
        """Checkpoint payload (same schema as the legacy driver)."""
        bundle = self._ensure_bundle()
        idx = bundle.caches.get("indexed")
        if idx is None:
            idx = get_engine("indexed").prepare(bundle.cfg, bundle.state)
        return {"ta_state": bundle.state.ta_state,
                "lists": idx.lists, "counts": idx.counts, "pos": idx.pos}

    def load_pytree(self, tree: dict) -> "TsetlinMachine":
        """Restore TA state + index; remaining caches re-prepare from state."""
        state = TMState(ta_state=tree["ta_state"])
        restored = indexing.ClauseIndex(
            lists=tree["lists"], counts=tree["counts"], pos=tree["pos"])
        caches = {key: (restored if key == "indexed"
                        else cache_provider(key).prepare(self.cfg, state))
                  for key in cache_keys_for(self.engines)}
        self.bundle = TMBundle(cfg=self.cfg, state=state, caches=caches)
        return self
