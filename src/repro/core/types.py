"""Core TM configuration and state containers.

Layout conventions (paper §2-§3):
  * ``o``        — number of input features; literal k < o is x_k, literal
                   k >= o is ¬x_{k-o}; total ``2o`` literals.
  * ``ta_state`` — int16 tensor ``(m, n, 2o)`` of Tsetlin Automaton states in
                   ``[1, 2N]``; action = include iff state > N.
  * clause polarity — clauses ``[0, n/2)`` are positive, ``[n/2, n)`` negative
                   (paper Eq. 2/3).
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class TMConfig:
    """Hyper-parameters of a (multiclass) Tsetlin Machine."""

    n_classes: int
    n_clauses: int          # clauses per class (half positive / half negative)
    n_features: int         # o
    n_states: int = 127     # N; state space is [1, 2N]
    s: float = 3.9          # specificity (reward/penalty split)
    threshold: int = 15     # T (vote clamp / annealing parameter)
    boost_true_positive: bool = False
    # Paper Eq. (4) counts never-falsified (incl. empty) clauses as true.
    # Classic TM inference outputs 0 for empty clauses. 1 == paper semantics.
    empty_clause_output: int = 1
    state_dtype: jnp.dtype = jnp.int16
    # Static engine-cache capacities (jit shapes must not depend on data):
    #   index_capacity  — per-literal inclusion-list rows (ClauseIndex);
    #                     None → worst case n_clauses.
    #   clause_capacity — per-clause included-literal rows ℓ_max
    #                     (CompactClauses); None → worst case 2o.
    # Tighter values trade memory/work for an overflow risk surfaced by
    # ``indexing.validate`` / ``indexing.validate_compact`` (cf. MoE expert
    # capacity factors).
    index_capacity: int | None = None
    clause_capacity: int | None = None
    # Kernel backend the TM primitives (clause_votes / clause_outputs /
    # ta_update) resolve through kernels/backend.py: 'auto' picks Pallas on
    # TPU and the XLA reference bodies elsewhere; 'pallas_interpret' runs the
    # kernel bodies through the Pallas interpreter (CI / debugging). Purely
    # an execution detail — results are bit-exact across backends, and the
    # checkpoint fingerprint ignores it.
    backend: str = "auto"

    def __post_init__(self):
        if self.n_clauses % 2:
            raise ValueError("n_clauses must be even (half per polarity)")
        from repro.kernels.backend import BACKENDS  # kernels/ is core-free
        if self.backend not in BACKENDS:
            raise ValueError(
                f"unknown kernel backend {self.backend!r}; one of {BACKENDS}")
        if self.empty_clause_output not in (0, 1):
            raise ValueError("empty_clause_output must be 0 or 1")
        if self.index_capacity is not None and self.index_capacity < 1:
            raise ValueError("index_capacity must be >= 1")
        if self.clause_capacity is not None and self.clause_capacity < 1:
            raise ValueError("clause_capacity must be >= 1")

    @property
    def n_literals(self) -> int:
        """2o — total literal count (positive + negated features)."""
        return 2 * self.n_features

    @property
    def half_clauses(self) -> int:
        """n/2 — clauses per polarity."""
        return self.n_clauses // 2

    @property
    def resolved_index_capacity(self) -> int:
        """Inclusion-list capacity (``index_capacity`` or the worst case)."""
        return self.index_capacity if self.index_capacity is not None else self.n_clauses

    @property
    def resolved_clause_capacity(self) -> int:
        """Per-clause literal capacity (``clause_capacity`` or worst case)."""
        return (self.clause_capacity if self.clause_capacity is not None
                else self.n_literals)


class TMState(NamedTuple):
    """Learnable state of a TM (a pytree; checkpointable/shardable)."""

    ta_state: jax.Array  # (m, n, 2o) int16 in [1, 2N]

    @property
    def n_classes(self) -> int:
        """m — classes (leading ``ta_state`` axis)."""
        return self.ta_state.shape[0]

    @property
    def n_clauses(self) -> int:
        """n — clause rows (possibly padded, see DESIGN.md §9)."""
        return self.ta_state.shape[1]

    @property
    def n_literals(self) -> int:
        """2o — literals (trailing ``ta_state`` axis)."""
        return self.ta_state.shape[2]


class VoteAccumulator(NamedTuple):
    """Double-buffered per-class vote sums for asynchronous sharded training.

    The Massively Parallel TM architecture (PAPERS.md, arXiv 2009.04861)
    shows clause blocks can apply Type I/II feedback against a slightly
    *stale* global vote sum instead of synchronising per evaluation. This
    pytree carries that staleness state in the ``TMBundle`` when a topology
    trains with ``async_votes=K`` (DESIGN.md §11):

      * ``local``    — (R, m) int32: each vote rank's latest *local* partial
                       vote sum per class (batch mean of the rounds it ran
                       since the last refresh; rows untouched in a window
                       keep their previous value). R is the number of vote
                       ranks — every (data × clause) mesh position.
      * ``stale``    — (R, m) int32: the read buffer — each rank's stale
                       estimate of the *remote* partial-vote sum per class
                       (the refresh-time global sum minus the rank's own
                       ``local`` row). The training round reads
                       ``live local + stale`` instead of psumming.
      * ``overflow`` — (R,) int32: cache-sync events dropped on this rank
                       since the last refresh; drained into the bundle's
                       global ``event_overflow`` by the refresh collective
                       (never by a per-step psum).

    The two (R, m) buffers are the double buffer: ``local`` accumulates
    while ``stale`` is read; one batched all-reduce every K steps
    (``distributed.make_vote_refresh``) swaps fresh sums into ``stale``.
    The accumulator is *rebuildable* state — checkpoints never persist it
    (a restore starts from zeros, a cold-start transient that decays within
    one refresh window), so async checkpoints stay topology-free.
    """

    local: jax.Array     # (R, m) int32 — latest local partial votes
    stale: jax.Array     # (R, m) int32 — stale remote vote sums (read buffer)
    overflow: jax.Array  # (R,)  int32 — per-rank dropped events since refresh


def init_tm(cfg: TMConfig, rng: jax.Array | None = None) -> TMState:
    """All TAs start just on the *exclude* side of the boundary (state N).

    This is the standard initialisation and the one the paper's index
    construction relies on: with every TA excluding, all inclusion lists
    start empty.
    """
    del rng  # deterministic init; rng kept for API symmetry
    ta = jnp.full(
        (cfg.n_classes, cfg.n_clauses, cfg.n_literals),
        cfg.n_states,
        dtype=cfg.state_dtype,
    )
    return TMState(ta_state=ta)


def literals_from_input(x: jax.Array) -> jax.Array:
    """(…, o) {0,1} input → (…, 2o) literal truth values [x, ¬x]."""
    x = x.astype(jnp.uint8)
    return jnp.concatenate([x, 1 - x], axis=-1)


def include_mask(cfg: TMConfig, state: TMState) -> jax.Array:
    """(m, n, 2o) bool — TA action is *include*."""
    return state.ta_state > cfg.n_states


def clause_polarity(cfg: TMConfig) -> jax.Array:
    """(n,) int32 — +1 for positive clauses, -1 for negative."""
    return jnp.where(
        jnp.arange(cfg.n_clauses) < cfg.half_clauses, 1, -1
    ).astype(jnp.int32)
