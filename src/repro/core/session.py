"""Topology-aware TM execution: one estimator surface for every placement.

The clause-indexing paper's engines are placement-agnostic by construction
(DESIGN.md §6: the sharded unit is the whole ``TMBundle``); what was missing
was a single front door. This module is that door:

  * ``Topology`` — a declarative placement spec: how many clause shards
    (the Massively Parallel TM partitioning axis), how many data shards
    (batch axis for inference / batch-parallel learning; extra clause
    parallelism for sequential learning — see ``distributed.py``), which
    engines to maintain, and whether train steps donate their input bundle.
  * ``TMSession`` — resolves a ``Topology`` **once** into either the
    single-device jitted path (``api.train_step_jit`` / ``api._scores_jit``)
    or the shard_map path (``distributed.make_sharded_*`` over a host mesh),
    and exposes placement-transparent ``prepare`` / ``train_step`` /
    ``scores`` / ``predict``. Both resolutions are bit-exact for the same
    seed (full-draw rand slicing), so a topology is a deployment detail —
    the property tests/test_tm_session.py pins.
  * ``TsetlinMachine`` — the stateful estimator facade over a session
    (init / fit / partial_fit / predict / scores / evaluate, plus the
    versioned ``save`` / ``load`` checkpoint API). ``fit`` pads a trailing
    partial batch to the compiled shape with a sample mask — no recompile,
    no dropped samples.

Serving (``launch/tm_serve.py``) and fault-tolerant training
(``runtime/tm_task.py``) drive the same session object; checkpoints persist
state + config fingerprint only (``checkpoint/tm_store.py``) and rebuild
caches on the restoring session's topology (reshard-on-restore).
"""
from __future__ import annotations

import dataclasses
from typing import Iterable

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding

from repro.core import api, indexing
from repro.core.api import (
    DEFAULT_ENGINE, TMBundle, init_bundle, train_step_jit)
from repro.core.engines import CLAUSE_AXIS, registered_engines
from repro.core.types import TMConfig, TMState, init_tm


@dataclasses.dataclass(frozen=True)
class ScoresLowering:
    """One padded batch shape's scores graph, staged for AOT compilation.

    Produced by ``TMSession.lower_scores`` and consumed by the serving AOT
    bucket cache (``serving/aot.py``): ``lowered.compile()`` yields the
    executable once at startup, and the hot serving loop only ever calls
    ``bind(compiled, x)`` — which closes over the (fixed) serving bundle's
    operands, so a dispatch can never retrace or recompile.

    ``x_sharding`` is the placement a ``(batch_size, n_features)`` uint8
    batch must land on before ``bind`` (None on a single-device session:
    any uncommitted array is accepted).
    """

    lowered: object            # jax.stages.Lowered
    bind: object               # (compiled, x) -> (batch_size, m) scores
    x_sharding: object | None  # NamedSharding of the batch operand (or None)
    batch_size: int
    engine: str


# AOT serving jits for the single-device path, keyed by the donate-x flag —
# module-level for the same reason as api._scores_jit: every session and
# estimator shares one XLA compilation cache.
_AOT_SCORES_JIT: dict[bool, object] = {}


def _aot_scores_jit(donate_x: bool):
    fn = _AOT_SCORES_JIT.get(donate_x)
    if fn is None:
        fn = jax.jit(api.bundle_scores, static_argnames=("engine",),
                     donate_argnums=(1,) if donate_x else ())
        _AOT_SCORES_JIT[donate_x] = fn
    return fn


@dataclasses.dataclass(frozen=True)
class Topology:
    """Declarative placement for a TM: resolved once by ``TMSession``.

    ``clause_shards``  — ways the clause axis splits over the mesh ``model``
                         axis (1 → no clause sharding).
    ``data_shards``    — ways the batch splits over the mesh ``data`` axis
                         for inference and batch-parallel learning; for
                         sequential learning the data axis instead composes
                         with the clause axis (hierarchical data×clause
                         sharding, ``distributed.make_sharded_train_step``).
    ``engines``        — engine names whose caches the bundle maintains
                         (None → every registered engine).
    ``donate``         — train steps donate the input bundle's buffers
                         (None → wherever the backend implements donation).
    ``backend``        — kernel backend the TM primitives resolve through
                         (``kernels/backend.py``): ``'auto'`` | ``'xla'`` |
                         ``'pallas'`` | ``'pallas_interpret'``; None defers
                         to ``TMConfig.backend``. Placement and kernel
                         choice are declared in one spot and resolved once.
    ``async_votes``    — K > 0 trains clause shards *asynchronously* against
                         a K-step-stale vote sum (DESIGN.md §11): no vote
                         collective inside the step, one batched all-reduce
                         per K steps refreshes the ``VoteAccumulator``.
                         0 (default) keeps the bit-exact synchronous
                         semantics. An execution knob like ``backend``:
                         checkpoints ignore it.
    """

    clause_shards: int = 1
    data_shards: int = 1
    engines: tuple[str, ...] | None = None
    donate: bool | None = None
    backend: str | None = None
    async_votes: int = 0

    def __post_init__(self):
        if self.clause_shards < 1 or self.data_shards < 1:
            raise ValueError(
                f"Topology shard counts must be >= 1, got clause_shards="
                f"{self.clause_shards}, data_shards={self.data_shards}")
        if self.async_votes < 0:
            raise ValueError(
                f"async_votes must be >= 0 (0 = synchronous), got "
                f"{self.async_votes}")
        if self.engines is not None and not isinstance(self.engines, tuple):
            object.__setattr__(self, "engines", tuple(self.engines))
        if self.backend is not None:
            from repro.kernels.backend import BACKENDS
            if self.backend not in BACKENDS:
                raise ValueError(
                    f"unknown kernel backend {self.backend!r}; one of "
                    f"{BACKENDS}")

    @property
    def n_devices(self) -> int:
        """Devices this topology occupies (``clause_shards · data_shards``)."""
        return self.clause_shards * self.data_shards

    @property
    def is_sharded(self) -> bool:
        """True when the topology needs a mesh (more than one device)."""
        return self.n_devices > 1

    def describe(self) -> dict:
        """Machine-readable placement summary (benchmarks record this)."""
        return {"clause_shards": self.clause_shards,
                "data_shards": self.data_shards,
                "devices": self.n_devices,
                "async_votes": self.async_votes}


def _topology_of_mesh(mesh, engines, donate) -> Topology:
    """Derive the Topology an explicit mesh implements."""
    clause = mesh.shape.get(CLAUSE_AXIS, 1)
    data = 1
    for a in ("pod", "data"):
        data *= mesh.shape.get(a, 1)
    return Topology(clause_shards=clause, data_shards=data,
                    engines=engines, donate=donate)


class TMSession:
    """One resolved (config × topology): placement-transparent execution.

    Resolution happens once, here: a 1-device topology binds the jitted
    single-device functions; anything larger builds (or adopts) a mesh and
    binds the shard_map factories. Every method downstream —
    ``prepare`` / ``train_step`` / ``scores`` / ``predict`` — has identical
    semantics and bit-exact results across resolutions.

    Pass ``mesh=`` to adopt an existing mesh (the trainer's, a production
    pod slice) instead of building a host mesh from the shard counts.
    """

    def __init__(self, cfg: TMConfig, topology: Topology | None = None, *,
                 mesh=None, engines: Iterable[str] | None = None,
                 parallel: bool = False, max_events: int = 4096):
        if topology is None:
            topology = Topology(
                engines=tuple(engines) if engines is not None else None)
        elif engines is not None:
            if (topology.engines is not None
                    and topology.engines != tuple(engines)):
                raise ValueError(
                    f"conflicting engines: topology says {topology.engines}, "
                    f"call says {tuple(engines)}")
            topology = dataclasses.replace(topology, engines=tuple(engines))
        if mesh is not None:
            adopted = _topology_of_mesh(mesh, topology.engines,
                                        topology.donate)
            topology = dataclasses.replace(adopted, backend=topology.backend,
                                           async_votes=topology.async_votes)
        if topology.backend is not None and topology.backend != cfg.backend:
            # the topology's kernel choice wins: everything downstream —
            # engines, the training round, the shard_map factories — reads
            # cfg.backend, so resolve the override into the config once here
            cfg = dataclasses.replace(cfg, backend=topology.backend)
        self.cfg = cfg
        self.topology = topology
        self.parallel = parallel
        self.max_events = max_events
        self.engines = (topology.engines if topology.engines is not None
                        else registered_engines())
        self._scores_fns: dict[str, object] = {}
        self._refresh = None
        self._pending_steps = 0  # steps since the last stale-vote refresh

        if not topology.is_sharded:
            if topology.async_votes > 0:
                raise ValueError(
                    f"Topology(async_votes={topology.async_votes}) needs a "
                    "sharded placement — on a single device there is no "
                    "vote collective to make asynchronous; use "
                    "clause_shards/data_shards > 1 (or async_votes=0)")
            self.mesh = None
            self.geometry = None
            self._prepare = None
            self._step = None
            return

        from repro.core import distributed  # sharded resolution only
        if mesh is None:
            from repro.launch.mesh import make_host_mesh
            try:
                mesh = make_host_mesh(data=topology.data_shards,
                                      model=topology.clause_shards)
            except RuntimeError as e:
                raise RuntimeError(
                    f"Topology(clause_shards={topology.clause_shards}, "
                    f"data_shards={topology.data_shards}) needs "
                    f"{topology.n_devices} devices: {e}") from None
        self.mesh = mesh
        # ragged clause geometry + the sequential composition rule this
        # (cfg × mesh) resolves to (DESIGN.md §9) — any shard counts compose;
        # make_sharded_train_step warns when the rule is 'replicated'
        self.geometry = distributed.geometry(cfg, mesh)
        self._prepare = distributed.make_sharded_prepare(
            cfg, mesh, engines=self.engines,
            async_votes=topology.async_votes)
        self._step = distributed.make_sharded_train_step(
            cfg, mesh, engines=self.engines, parallel=parallel,
            max_events=max_events, donate=topology.donate,
            async_votes=topology.async_votes)
        if topology.async_votes > 0:
            self._refresh = distributed.make_vote_refresh(
                cfg, mesh, parallel=parallel, donate=topology.donate)

    # -- placement ----------------------------------------------------------

    @property
    def is_sharded(self) -> bool:
        """True when this session resolved onto a mesh (shard_map path)."""
        return self.mesh is not None

    def state_sharding(self):
        """Target sharding of the bundle's ``ta_state`` (None = any).

        Under a ragged clause geometry the sharded array is the *padded*
        state (``geometry.n_padded`` clause rows), so this sharding does
        not apply to an unpadded global state — ``prepare`` pads first.
        """
        if self.mesh is None:
            return None
        from repro.core.distributed import STATE_PSPEC
        return NamedSharding(self.mesh, STATE_PSPEC.ta_state)

    def unpad_state(self, state: TMState) -> TMState:
        """Global ``(m, n_clauses, 2o)`` view of a (possibly padded) state.

        Sharded bundles carry the ragged clause layout (DESIGN.md §9);
        everything user-facing — the estimator's ``state`` property,
        checkpoints, cross-topology comparisons — goes through this view,
        so padding never leaks out of the session.
        """
        if self.geometry is None or not self.geometry.ragged_clauses:
            return state
        from repro.core import distributed
        return distributed.unpad_state(self.cfg, state)

    def describe(self) -> dict:
        """Placement summary + the resolved backend and composition rule.

        ``composition`` names the sequential-learning rule the topology
        resolved to (``composed_even`` / ``composed_ragged`` /
        ``replicated`` / ``clause_only``; ``single`` on one device,
        ``batch_parallel`` when the session runs the parallel learning
        mode) — recorded in BENCH_tm_serve.json topology metadata.
        ``shard_rows`` is the per-clause-shard row census
        (``[{shard, real_rows, pad_rows}]``): where the ragged clause
        padding actually lands (all of it on the trailing shard(s), §9).
        """
        from repro.kernels.backend import resolve_backend
        d = self.topology.describe()
        d["sharded"] = self.is_sharded
        d["backend"] = resolve_backend(self.cfg.backend)
        if self.geometry is None:
            d["composition"] = "single"
            d["shard_rows"] = [{"shard": 0, "real_rows": self.cfg.n_clauses,
                                "pad_rows": 0}]
        else:
            d["composition"] = ("batch_parallel" if self.parallel
                                else self.geometry.composition)
            d["shard_rows"] = self.geometry.shard_rows()
        return d

    # -- bundle lifecycle ---------------------------------------------------

    def prepare(self, state: TMState) -> TMBundle:
        """Bundle with this session's caches built from ``state`` (placed
        per the topology; sharded caches are built shard-locally)."""
        if self._prepare is not None:
            return self._prepare(state)
        return init_bundle(self.cfg, engines=self.engines, state=state)

    def init_bundle(self, rng: jax.Array | None = None) -> TMBundle:
        """Freshly initialised bundle (all TAs exclude), placed and cached
        per this session's topology."""
        return self.prepare(init_tm(self.cfg, rng))

    # -- execution ----------------------------------------------------------

    def train_step(self, bundle: TMBundle, xs, ys, rng,
                   mask=None) -> TMBundle:
        """One learning step (all maintained caches stay in sync). The
        input bundle is donated when the topology says so — do not read it
        afterwards.

        Under ``async_votes=K`` the step itself performs no vote
        collective; the session counts steps and chains the stale-vote
        refresh (one batched all-reduce) onto every K-th step — the
        cadence is host-side state, so the step executable stays
        collective-clean for the dry-run's HLO assertions.
        """
        if self._step is not None:
            d = self.topology.data_shards
            if self.parallel and xs.shape[0] % d:
                raise ValueError(
                    f"batch size {xs.shape[0]} does not divide over "
                    f"data_shards={d} (batch-parallel learning shards the "
                    "batch); pick a divisible batch_size")
            bundle = self._step(bundle, xs, ys, rng, mask)
            if self._refresh is not None:
                self._pending_steps += 1
                if self._pending_steps >= self.topology.async_votes:
                    bundle = self._refresh(bundle)
                    self._pending_steps = 0
            return bundle
        return train_step_jit(bundle, xs, ys, rng, mask,
                              parallel=self.parallel,
                              max_events=self.max_events,
                              donate=self.topology.donate)

    def refresh_votes(self, bundle: TMBundle) -> TMBundle:
        """Force a stale-vote refresh now (resets the K-step cadence).

        No-op outside async mode. Useful before an accuracy read or a
        checkpoint when mid-window staleness matters; also drains the
        accumulated per-rank overflow counts into ``bundle.event_overflow``
        (between refreshes the bundle's counter deliberately lags —
        overflow accounting rides the refresh collective, never a per-step
        psum).
        """
        if self._refresh is None:
            return bundle
        self._pending_steps = 0
        return self._refresh(bundle)

    def _sharded_scores_fn(self, engine: str):
        """Memoised ``make_sharded_scores`` wrapper for one engine."""
        fn = self._scores_fns.get(engine)
        if fn is None:
            from repro.core.distributed import make_sharded_scores
            fn = make_sharded_scores(self.cfg, self.mesh, engine=engine)
            self._scores_fns[engine] = fn
        return fn

    def scores(self, bundle: TMBundle, x, *,
               engine: str = DEFAULT_ENGINE) -> jax.Array:
        """(B, o) inputs → (B, m) class scores through a registry engine
        (the single-device jitted graph, or the sharded one-all-reduce
        scores path when this session holds a mesh)."""
        if self.mesh is None:
            return api._scores_jit(bundle, x, engine=engine)
        return self._sharded_scores_fn(engine)(bundle, x)

    def fingerprint(self) -> str:
        """Short stable id of (config × resolved placement × backend).

        Part of the AOT serving cache key (``serving/aot.py``): two
        sessions share compiled bucket executables only when their configs
        fingerprint-match *and* they resolved to the same placement,
        composition rule, and kernel backend. Built from the checkpoint
        config fingerprint (which deliberately ignores ``backend``) plus
        ``describe()`` (which records the resolved backend), so a backend
        switch changes the serving key without invalidating checkpoints.
        """
        import hashlib

        from repro.checkpoint.tm_store import config_fingerprint
        blob = repr(sorted(self.describe().items())).encode()
        blob += bytes(bytearray(config_fingerprint(self.cfg)))
        return hashlib.sha256(blob).hexdigest()[:16]

    def lower_scores(self, bundle: TMBundle, batch_size: int, *,
                     engine: str = DEFAULT_ENGINE,
                     donate_x: bool = False) -> ScoresLowering:
        """Stage the scores graph for one padded batch shape (AOT hook).

        The returned ``ScoresLowering`` separates the three serving phases
        the hot loop must never mix: ``lowered`` (trace + lower, done
        here), ``lowered.compile()`` (done once per bucket by
        ``serving/aot.py``, timed separately), and ``bind(compiled, x)``
        (the only thing a dispatch calls). ``bind`` closes over *this*
        bundle's operands — the sharded resolution binds the prepared
        shard-local cache (or the TA state for cache-less engines) with
        explicit in/out shardings, the single-device resolution binds the
        bundle through the shared AOT jit. ``donate_x`` donates the batch
        operand's buffer to the executable (pass
        ``api.resolve_donate(None)`` to donate wherever the backend
        implements it).
        """
        x_spec = jax.ShapeDtypeStruct((batch_size, self.cfg.n_features),
                                      jnp.uint8)
        if self.mesh is None:
            fn = _aot_scores_jit(donate_x)
            lowered = fn.lower(bundle, x_spec, engine=engine)

            def bind(compiled, x):
                return compiled(bundle, x)

            return ScoresLowering(lowered=lowered, bind=bind,
                                  x_sharding=None, batch_size=batch_size,
                                  engine=engine)

        sfn = self._sharded_scores_fn(engine)
        operand = sfn.operand(bundle)
        x_sharding = NamedSharding(self.mesh, sfn.bspec)
        x_spec = jax.ShapeDtypeStruct(x_spec.shape, x_spec.dtype,
                                      sharding=x_sharding)
        lowered = sfn.aot_jit(donate_x).lower(operand, sfn.pol, x_spec)

        def bind(compiled, x):
            return compiled(operand, sfn.pol, x)

        return ScoresLowering(lowered=lowered, bind=bind,
                              x_sharding=x_sharding, batch_size=batch_size,
                              engine=engine)

    def predict(self, bundle: TMBundle, x, *,
                engine: str = DEFAULT_ENGINE) -> jax.Array:
        """(B, o) inputs → (B,) argmax class through a registry engine."""
        if self.mesh is None:
            return api._predict_jit(bundle, x, engine=engine)
        return jnp.argmax(self.scores(bundle, x, engine=engine), axis=-1)

    # -- checkpointing (schema v1: state + config fingerprint) --------------

    def save(self, directory, bundle: TMBundle, *, step: int = 0,
             keep: int = 3, blocking: bool = True) -> None:
        """Write a schema-v1 checkpoint of the bundle's global TA state.

        Always the unpadded ``(m, n_clauses, 2o)`` view — checkpoints are
        topology-free, so a state saved under a ragged placement loads
        bit-exactly anywhere (and vice versa)."""
        from repro.checkpoint import tm_store
        ta = self.unpad_state(bundle.state).ta_state
        tm_store.save_tm(directory, self.cfg, ta,
                         step=step, keep=keep, blocking=blocking)

    def restore(self, directory, *, step: int | None = None):
        """(bundle, step) from a schema-v1 checkpoint: the TA state lands on
        this session's placement and every cache rebuilds on this topology
        (reshard-on-restore — caches are never persisted). Under a ragged
        clause geometry the checkpointed global state cannot land directly
        on the mesh (the sharded layout is the padded one), so it loads
        unplaced and ``prepare`` pads + places it."""
        from repro.checkpoint import tm_store
        like = jax.ShapeDtypeStruct(
            (self.cfg.n_classes, self.cfg.n_clauses, self.cfg.n_literals),
            self.cfg.state_dtype)
        sharding = (None if (self.geometry is not None
                             and self.geometry.ragged_clauses)
                    else self.state_sharding())
        ta, step = tm_store.load_tm(directory, self.cfg, like, step=step,
                                    sharding=sharding)
        return self.prepare(TMState(ta_state=ta)), step


class TsetlinMachine:
    """Estimator facade over a ``TMSession``.

    >>> machine = TsetlinMachine(cfg, topology=Topology(clause_shards=4))
    >>> machine.init().fit(xs, ys, epochs=3, batch_size=128)
    >>> machine.predict(x_test, engine="indexed")

    The topology is transparent: the same script runs single-device, clause
    sharded, or data×clause sharded, bit-exactly. Every heavy call delegates
    to the session's jitted pure functions; the facade only owns the bundle
    reference and the RNG chain.
    """

    def __init__(
        self,
        cfg: TMConfig,
        *,
        topology: Topology | None = None,
        engines: Iterable[str] | None = None,
        parallel: bool = False,
        max_events_per_batch: int = 4096,
        seed: int = 0,
    ):
        self.session = TMSession(cfg, topology, engines=engines,
                                 parallel=parallel,
                                 max_events=max_events_per_batch)
        self.cfg = self.session.cfg  # topology backend override resolved in
        self.engines = self.session.engines
        self.parallel = parallel
        self.max_events_per_batch = max_events_per_batch
        self._key = jax.random.key(seed)
        self.bundle: TMBundle | None = None

    @property
    def topology(self) -> Topology:
        """The placement this machine's session resolved."""
        return self.session.topology

    # -- lifecycle ----------------------------------------------------------

    def init(self, rng: jax.Array | None = None) -> "TsetlinMachine":
        """(Re)initialise the bundle on this machine's topology."""
        self.bundle = self.session.init_bundle(rng)
        return self

    def _ensure_bundle(self) -> TMBundle:
        if self.bundle is None:
            self.init()
        return self.bundle

    def _next_key(self, rng: jax.Array | None) -> jax.Array:
        if rng is not None:
            return rng
        self._key, sub = jax.random.split(self._key)
        return sub

    # -- learning -----------------------------------------------------------

    def partial_fit(self, xs, ys, rng: jax.Array | None = None, *,
                    mask=None) -> "TsetlinMachine":
        """One train step over a batch (all maintained caches kept in sync).
        ``mask`` (B,) bool marks valid rows — padded rows apply no update."""
        bundle = self._ensure_bundle()
        self.bundle = self.session.train_step(
            bundle, xs, ys, self._next_key(rng), mask)
        return self

    def fit(self, xs, ys, *, epochs: int = 1, batch_size: int | None = None,
            rng: jax.Array | None = None) -> "TsetlinMachine":
        """Epoch loop of ``partial_fit``; fixed-size minibatches when
        ``batch_size`` is set. A trailing partial batch pads to the compiled
        shape with a sample mask — every step reuses one compiled graph and
        every sample trains (padded rows are masked out)."""
        n = int(xs.shape[0])
        if batch_size is not None and n < batch_size:
            raise ValueError(
                f"batch_size={batch_size} exceeds dataset size "
                f"{n}: fit would perform zero steps")
        key = self._next_key(rng)
        for _ in range(epochs):
            if batch_size is None:
                key, sub = jax.random.split(key)
                self.partial_fit(xs, ys, sub)
                continue
            for start in range(0, n, batch_size):
                key, sub = jax.random.split(key)
                k = min(batch_size, n - start)
                xb, yb = xs[start:start + k], ys[start:start + k]
                mask = None  # full batches skip the masking work entirely
                if k < batch_size:  # pad to the compiled shape, mask the rest
                    pad = batch_size - k
                    xb = jnp.concatenate(
                        [jnp.asarray(xb),
                         jnp.zeros((pad,) + tuple(xs.shape[1:]),
                                   jnp.asarray(xb).dtype)])
                    yb = jnp.concatenate(
                        [jnp.asarray(yb),
                         jnp.zeros((pad,), jnp.asarray(yb).dtype)])
                    mask = jnp.arange(batch_size) < k
                self.partial_fit(xb, yb, sub, mask=mask)
        return self

    # -- inference ----------------------------------------------------------

    def scores(self, xs, *, engine: str = DEFAULT_ENGINE) -> jax.Array:
        """(B, o) inputs → (B, m) class scores through a registry engine."""
        return self.session.scores(self._ensure_bundle(), xs, engine=engine)

    def predict(self, xs, *, engine: str = DEFAULT_ENGINE) -> jax.Array:
        """(B, o) inputs → (B,) argmax class through a registry engine."""
        return self.session.predict(self._ensure_bundle(), xs, engine=engine)

    def evaluate(self, xs, ys, *, engine: str = DEFAULT_ENGINE) -> float:
        """Mean prediction accuracy of ``xs`` against labels ``ys``."""
        return float(jnp.mean(
            (self.predict(xs, engine=engine) == ys).astype(jnp.float32)))

    # -- state access / persistence -----------------------------------------

    @property
    def event_overflow(self) -> int:
        """Cache-sync events dropped since the bundle was prepared.

        Non-zero means ``max_events_per_batch`` was too small for some step
        and the engine caches are stale mirrors — a config error. Checking
        costs one scalar device read, so callers can assert
        ``machine.event_overflow == 0`` after every step (or epoch) instead
        of sizing the buffer to the ``n_classes·n_clauses·n_literals``
        worst case up front. Note the buffer is per clause shard
        (DESIGN.md §6): a sharded topology holds ``clause_shards ×
        max_events_per_batch`` crossings in total, so size the buffer for
        the placement with the *fewest* clause shards you intend to run —
        a limit that held on ``Topology(clause_shards=4)`` may overflow on
        ``Topology(1)``.
        """
        bundle = self.bundle
        if bundle is None or bundle.event_overflow is None:
            return 0
        return int(jax.device_get(bundle.event_overflow))

    @property
    def state(self) -> TMState:
        """The global ``(m, n_clauses, 2o)`` TA state (never padded: any
        ragged clause-axis padding the sharded layout carries is stripped,
        so states compare bit-exactly across topologies)."""
        return self.session.unpad_state(self._ensure_bundle().state)

    @property
    def index(self) -> indexing.ClauseIndex:
        """The paper's clause index (shard-local layout when sharded)."""
        return self._ensure_bundle().index

    def save(self, directory, *, step: int = 0, keep: int = 3,
             blocking: bool = True) -> "TsetlinMachine":
        """Versioned checkpoint (schema v1): TA state + config fingerprint
        only. Engine caches are derived data and never persist — ``load``
        rebuilds them on the loading machine's topology."""
        self.session.save(directory, self._ensure_bundle(), step=step,
                          keep=keep, blocking=blocking)
        return self

    @classmethod
    def load(cls, directory, cfg: TMConfig, *,
             topology: Topology | None = None, step: int | None = None,
             **kwargs) -> "TsetlinMachine":
        """Restore onto any topology: the checkpointed state reshards to the
        new placement and caches rebuild there. Raises
        ``checkpoint.CheckpointMismatch`` when ``cfg`` does not fingerprint-
        match the checkpoint."""
        machine = cls(cfg, topology=topology, **kwargs)
        machine.bundle, _ = machine.session.restore(directory, step=step)
        return machine
