"""Pure-numpy oracle for TM semantics — the paper's pseudocode, literally.

Slow loops over classes/clauses/literals; used only in tests at small sizes
to pin the JAX implementation. Feedback consumes *injected* uniforms so it
can be replayed bit-exactly against the vectorised path.
"""
from __future__ import annotations

import numpy as np


def clause_outputs_ref(ta_state, x, n_states, empty_output=1):
    """ta_state: (m, n, 2o) ints; x: (o,) {0,1} → (m, n) uint8."""
    m, n, L = ta_state.shape
    o = L // 2
    lit = np.concatenate([x, 1 - x]).astype(np.uint8)
    out = np.zeros((m, n), np.uint8)
    for i in range(m):
        for j in range(n):
            include = ta_state[i, j] > n_states
            if not include.any():
                out[i, j] = empty_output
                continue
            out[i, j] = 1
            for k in range(L):
                if include[k] and lit[k] == 0:
                    out[i, j] = 0
                    break
    return out


def votes_ref(clause_out):
    """(m, n) clause outputs → (m,) vote sums (first half positive)."""
    m, n = clause_out.shape
    half = n // 2
    return (
        clause_out[:, :half].astype(np.int64).sum(-1)
        - clause_out[:, half:].astype(np.int64).sum(-1)
    )


def class_round_ref(ta_row, lit, clause_gate_u, type_i_u, *,
                    n_states, s, threshold, half, positive_round,
                    boost_true_positive=False):
    """Numpy replica of tm._class_round for one class. Returns new (n, 2o)."""
    n, L = ta_row.shape
    ta = ta_row.astype(np.int64).copy()
    include = ta_row > n_states
    clause_out = np.ones(n, np.uint8)
    for j in range(n):
        for k in range(L):
            if include[j, k] and lit[k] == 0:
                clause_out[j] = 0
                break
    votes = 0
    for j in range(n):
        votes += int(clause_out[j]) * (1 if j < half else -1)
    t = float(threshold)
    votes = max(-t, min(t, votes))
    p = (t - votes) / (2 * t) if positive_round else (t + votes) / (2 * t)
    inv_s = 1.0 / s
    p_reward = 1.0 if boost_true_positive else 1.0 - inv_s
    for j in range(n):
        if not (clause_gate_u[j] < p):
            continue
        gets_type_i = (j < half) if positive_round else (j >= half)
        if gets_type_i:
            for k in range(L):
                u = type_i_u[j, k]
                if clause_out[j] == 1 and lit[k] == 1:
                    if u < p_reward:
                        ta[j, k] += 1
                elif u < inv_s:
                    ta[j, k] -= 1
        else:  # Type II
            if clause_out[j] == 1:
                for k in range(L):
                    if lit[k] == 0 and not include[j, k]:
                        ta[j, k] += 1
    return np.clip(ta, 1, 2 * n_states)


def indexed_scores_ref(lists, counts, x, n_clauses):
    """Paper §3 inference with literal→clause lists (numpy loops).

    lists: (m, 2o, cap); counts: (m, 2o); x: (o,) → (m,) scores (Eq. 4).
    """
    m, L, _ = lists.shape
    o = L // 2
    lit = np.concatenate([x, 1 - x]).astype(np.uint8)
    half = n_clauses // 2
    scores = np.zeros(m, np.int64)
    for i in range(m):
        falsified = np.zeros(n_clauses, bool)
        for k in range(L):
            if lit[k] == 0:
                for c in range(counts[i, k]):
                    falsified[lists[i, k, c]] = True
        fp = falsified[:half].sum()
        fn = falsified[half:].sum()
        scores[i] = fn - fp
    return scores
