"""Bit-packing of literal/include vectors into uint32 words.

The dense-evaluation hot path packs 32 literals per lane word:
  clause falsified  ⇔  any_w( include_w & ~literal_w ) != 0
This is the VPU-friendly dense layout the Pallas kernel tiles over.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

WORD = 32


def n_words(n_bits: int) -> int:
    return (n_bits + WORD - 1) // WORD


def pack_bits(bits: jax.Array) -> jax.Array:
    """(…, K) {0,1} → (…, ceil(K/32)) uint32 (little-endian bit order)."""
    k = bits.shape[-1]
    w = n_words(k)
    pad = w * WORD - k
    if pad:
        bits = jnp.concatenate(
            [bits, jnp.zeros(bits.shape[:-1] + (pad,), bits.dtype)], axis=-1
        )
    b = bits.astype(jnp.uint32).reshape(bits.shape[:-1] + (w, WORD))
    shifts = jnp.arange(WORD, dtype=jnp.uint32)
    return jnp.sum(b << shifts, axis=-1, dtype=jnp.uint32)


def unpack_bits(words: jax.Array, n_bits: int) -> jax.Array:
    """(…, W) uint32 → (…, n_bits) uint8."""
    shifts = jnp.arange(WORD, dtype=jnp.uint32)
    bits = (words[..., None] >> shifts) & jnp.uint32(1)
    bits = bits.reshape(words.shape[:-1] + (words.shape[-1] * WORD,))
    return bits[..., :n_bits].astype(jnp.uint8)


def packed_literals(x: jax.Array) -> jax.Array:
    """(…, o) {0,1} features → (…, ceil(2o/32)) packed [x, ¬x] literals."""
    lit = jnp.concatenate([x.astype(jnp.uint8), 1 - x.astype(jnp.uint8)], axis=-1)
    return pack_bits(lit)
