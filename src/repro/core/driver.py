"""TM training/serving driver — the paper's system glued to the substrate.

Maintains the dense TA states (TPU-friendly learning) AND the paper's
clause index, kept in sync event-wise after every batch (O(1) per boundary
crossing — core/indexing.py). Inference can run through any engine:

  * "dense"    — exhaustive baseline (paper's comparison point)
  * "bitpack"  — Pallas fused eval+vote kernel
  * "compact"  — gather over included literals (sparsity-proportional work)
  * "indexed"  — the paper's falsification index (Eq. 4)

Checkpointing reuses repro.checkpoint (TA states + index are one pytree).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import indexing, tm
from repro.core.types import TMConfig, TMState, include_mask, init_tm
from repro.kernels import ops as kops


@dataclasses.dataclass
class TMDriver:
    cfg: TMConfig
    state: TMState
    index: indexing.ClauseIndex
    max_events_per_batch: int = 4096

    @staticmethod
    def create(cfg: TMConfig, capacity: int | None = None) -> "TMDriver":
        cap = capacity or cfg.n_clauses
        return TMDriver(cfg=cfg, state=init_tm(cfg),
                        index=indexing.empty_index(cfg, cap))

    # -- learning -------------------------------------------------------------

    def train_batch(self, xs, ys, rng, *, parallel: bool = False,
                    sync_index: bool = True):
        old_inc = include_mask(self.cfg, self.state)
        upd = (tm.update_batch_parallel if parallel
               else tm.update_batch_sequential)
        self.state = upd(self.cfg, self.state, xs, ys, rng)
        if sync_index:
            new_inc = include_mask(self.cfg, self.state)
            events = indexing.events_from_transition(
                old_inc, new_inc, self.max_events_per_batch)
            self.index = indexing.apply_events(self.index, events)
        return self

    def rebuild_index(self):
        self.index = indexing.build_index(self.cfg, self.state,
                                          self.index.capacity)
        return self

    # -- inference ------------------------------------------------------------

    def scores(self, xs, *, engine: str = "indexed"):
        if engine == "dense":
            return tm.scores(self.cfg, self.state, xs)
        if engine == "bitpack":
            return kops.tm_votes(self.cfg, self.state, xs)
        if engine == "bitpack_xla":
            return tm.bitpacked_scores(self.cfg, self.state, xs)
        if engine == "compact":
            lmax = int(np.asarray(
                include_mask(self.cfg, self.state).sum(-1)).max())
            comp = indexing.compact(self.cfg, self.state, max(lmax, 1))
            return indexing.compact_scores(self.cfg, comp, xs)
        if engine == "indexed":
            return indexing.indexed_scores(self.cfg, self.index, xs)
        raise ValueError(engine)

    def predict(self, xs, *, engine: str = "indexed"):
        return jnp.argmax(self.scores(xs, engine=engine), axis=-1)

    def accuracy(self, xs, ys, *, engine: str = "indexed") -> float:
        return float(jnp.mean(
            (self.predict(xs, engine=engine) == ys).astype(jnp.float32)))

    # -- persistence ----------------------------------------------------------

    def as_pytree(self):
        return {"ta_state": self.state.ta_state,
                "lists": self.index.lists,
                "counts": self.index.counts,
                "pos": self.index.pos}

    def load_pytree(self, tree):
        self.state = TMState(ta_state=tree["ta_state"])
        self.index = indexing.ClauseIndex(
            lists=tree["lists"], counts=tree["counts"], pos=tree["pos"])
        return self
