"""DEPRECATED: ``TMDriver`` is a thin shim over the engine-registry API.

Use ``repro.core.api.TsetlinMachine`` (estimator facade) or the pure
functions ``repro.core.api.train_step`` / ``bundle_scores`` directly. This
shim keeps the seed's surface (``create`` / ``train_batch`` / ``scores`` /
``predict`` / ``accuracy`` / ``as_pytree`` / ``load_pytree``) alive for old
scripts; all dispatch now goes through ``repro.core.engines`` — there is no
per-engine ``if/elif`` and no host sync left here.
"""
from __future__ import annotations

import warnings

import jax.numpy as jnp

from repro.core import api, indexing
from repro.core.engines import cache_provider, get_engine
from repro.core.types import TMConfig, TMState, init_tm


class TMDriver:
    """Legacy facade; state lives in an ``api.TMBundle``."""

    def __init__(self, cfg: TMConfig, state: TMState | None = None,
                 index: indexing.ClauseIndex | None = None,
                 max_events_per_batch: int = 4096):
        warnings.warn(
            "TMDriver is deprecated; use repro.core.api.TsetlinMachine "
            "(or the pure train_step/bundle_scores functions).",
            DeprecationWarning, stacklevel=2)
        state = state if state is not None else init_tm(cfg)
        # Legacy semantics: only the paper's index is maintained event-wise;
        # every other engine evaluates fresh from the current state (so
        # sync_index=False leaves only the index stale, exactly as before).
        caches = {"indexed": (index if index is not None
                              else get_engine("indexed").prepare(cfg, state))}
        self.bundle = api.TMBundle(cfg=cfg, state=state, caches=caches)
        self.max_events_per_batch = max_events_per_batch

    @staticmethod
    def create(cfg: TMConfig, capacity: int | None = None) -> "TMDriver":
        if capacity is not None:
            import dataclasses
            cfg = dataclasses.replace(cfg, index_capacity=capacity)
        return TMDriver(cfg=cfg)

    # -- legacy attribute surface ---------------------------------------------

    @property
    def cfg(self) -> TMConfig:
        return self.bundle.cfg

    @property
    def state(self) -> TMState:
        return self.bundle.state

    @state.setter
    def state(self, state: TMState):
        # rebuild only the caches this bundle carries, preserving their
        # capacities (a caller-supplied index may be tighter than cfg's)
        cfg = self.bundle.cfg
        caches = {}
        for key, old in self.bundle.caches.items():
            if key == "indexed":
                caches[key] = indexing.build_index(cfg, state, old.capacity)
            else:
                caches[key] = cache_provider(key).prepare(cfg, state)
        self.bundle = api.TMBundle(cfg=cfg, state=state, caches=caches)

    @property
    def index(self) -> indexing.ClauseIndex:
        return self.bundle.index

    # -- learning -------------------------------------------------------------

    def train_batch(self, xs, ys, rng, *, parallel: bool = False,
                    sync_index: bool = True):
        if sync_index:
            self.bundle = api.train_step_jit(
                self.bundle, xs, ys, rng, parallel=parallel,
                max_events=self.max_events_per_batch)
        else:
            # states only; caches go stale (legacy behaviour of sync_index=False)
            from repro.core import tm
            upd = (tm.update_batch_parallel if parallel
                   else tm.update_batch_sequential)
            new_state = upd(self.bundle.cfg, self.bundle.state, xs, ys, rng)
            self.bundle = api.TMBundle(cfg=self.bundle.cfg, state=new_state,
                                       caches=self.bundle.caches)
        return self

    def rebuild_index(self):
        caches = dict(self.bundle.caches)
        caches["indexed"] = get_engine("indexed").prepare(
            self.bundle.cfg, self.bundle.state)
        self.bundle = api.TMBundle(cfg=self.bundle.cfg,
                                   state=self.bundle.state, caches=caches)
        return self

    # -- inference (registry dispatch) ----------------------------------------

    def scores(self, xs, *, engine: str = api.DEFAULT_ENGINE):
        return api.bundle_scores(self.bundle, xs, engine=engine)

    def predict(self, xs, *, engine: str = api.DEFAULT_ENGINE):
        return jnp.argmax(self.scores(xs, engine=engine), axis=-1)

    def accuracy(self, xs, ys, *, engine: str = api.DEFAULT_ENGINE) -> float:
        return float(jnp.mean(
            (self.predict(xs, engine=engine) == ys).astype(jnp.float32)))

    # -- persistence ----------------------------------------------------------

    def as_pytree(self):
        idx = self.index
        return {"ta_state": self.state.ta_state,
                "lists": idx.lists, "counts": idx.counts, "pos": idx.pos}

    def load_pytree(self, tree):
        state = TMState(ta_state=tree["ta_state"])
        restored = indexing.ClauseIndex(
            lists=tree["lists"], counts=tree["counts"], pos=tree["pos"])
        caches = {key: (restored if key == "indexed"
                        else cache_provider(key).prepare(self.bundle.cfg, state))
                  for key in self.bundle.caches}
        self.bundle = api.TMBundle(cfg=self.bundle.cfg, state=state,
                                   caches=caches)
        return self
