"""Paper Tables 1–3 analogue: indexed vs exhaustive TM throughput.

Grid: (dataset-family × features × clauses), measuring
  * inference us/sample for every requested registry engine
    (default: dense | bitpack_xla | compact | indexed — ``bitpack_xla``
    is the backend-registry alias pinning the packed engine to the XLA
    body, so the grid times identically on every host; the
    ``backend_topology_sweep`` below covers the kernel routes),
  * training us/sample for dense learning with / without engine-cache
    maintenance (the jit-native ``api.train_step``),
  * the §3 'Remarks' WORK RATIO (indexed literal-inspections / dense),
    which is hardware-independent — the paper's 0.02 (MNIST) / 0.006 (IMDb)
    claims are validated here exactly.

Engine caches are prepared through the registry with *static* capacities
derived from the config (``index_capacity`` / ``clause_capacity`` at a 4×
expected-length capacity factor, cf. MoE expert capacity) — there is no
data-dependent host sync anywhere on the timed paths.

``run()`` returns machine-readable rows; ``main`` writes them to
``BENCH_tm.json`` so the perf trajectory is tracked across PRs.

Container scaling: sample counts and the clause grid are scaled down for
the 1-core CPU (the paper used full datasets on a desktop CPU); trends —
speedup grows with clause count, IMDb training slows down under index
maintenance — are the reproduction target, magnitudes are host-specific.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import platform
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.tm import fmnist_like, imdb_like, mnist_like
from repro.core import api, indexing, tm
from repro.core.engines import get_engine
from repro.core.types import TMConfig, TMState
from repro.data.synthetic import binarized_images, bow_documents

DEFAULT_ENGINES = ("dense", "bitpack_xla", "compact", "indexed")


def _timeit(fn, *args, reps=3, warmup=1):
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        best = min(best, time.perf_counter() - t0)
    return best


def synthetic_trained_state(cfg: TMConfig, avg_clause_len: float, seed=0):
    """TM state with paper-matched clause sparsity (include prob =
    avg_len / 2o), standing in for a trained machine's sparsity profile."""
    rng = np.random.default_rng(seed)
    p = avg_clause_len / cfg.n_literals
    inc = rng.uniform(size=(cfg.n_classes, cfg.n_clauses,
                            cfg.n_literals)) < p
    ta = np.where(inc, cfg.n_states + 1, cfg.n_states).astype(np.int16)
    return TMState(ta_state=jnp.asarray(ta))


def work_ratio(cfg: TMConfig, state: TMState, xs) -> float:
    """Paper §3 Remarks: (Σ_{k false} |L_k|) / (n·2o) per class-eval."""
    idx = indexing.build_index(cfg, state, cfg.resolved_index_capacity)
    w = np.asarray(indexing.indexed_work(idx, xs)).mean()
    return float(w) / indexing.dense_work(cfg)


def bench_cell(exp, n_clauses: int, *, engines=DEFAULT_ENGINES,
               n_eval=32, n_train=16, seed=0):
    # static cache capacities: 4× the expected list/clause length (cf. MoE
    # capacity factor); worst-case capacity makes the scatter/gather paths
    # do n/len× more masked work (§Perf hillclimb C)
    cap = min(n_clauses,
              max(16, int(4 * n_clauses * exp.avg_clause_len
                          / exp.tm.n_literals)))
    l_max = min(exp.tm.n_literals, max(16, int(4 * exp.avg_clause_len)))
    cfg = dataclasses.replace(exp.tm, n_clauses=n_clauses,
                              index_capacity=cap, clause_capacity=l_max)
    if exp.dataset == "image":
        xs, ys = binarized_images(n_eval + n_train, cfg.n_features,
                                  cfg.n_classes, seed=seed)
    else:
        xs, ys = bow_documents(n_eval + n_train, cfg.n_features,
                               cfg.n_classes, seed=seed)
    xs = jnp.asarray(xs)
    ys = jnp.asarray(ys)
    x_eval = xs[:n_eval]
    x_tr, y_tr = xs[n_eval:], ys[n_eval:]

    state = synthetic_trained_state(cfg, exp.avg_clause_len, seed)

    r: dict = {"family": exp.name, "features": cfg.n_features,
               "clauses": n_clauses, "engines": list(engines)}
    r["work_ratio"] = work_ratio(cfg, state, x_eval)

    # inference engines via the registry — caches prepared once (as during
    # learning), passed as jit ARGS (a closure constant triggers multi-second
    # XLA constant folding of the packed tables and pollutes logs)
    for name in engines:
        eng = get_engine(name)
        cache = jax.jit(lambda s, e=eng: e.prepare(cfg, s))(state)
        fn = jax.jit(lambda c, x, e=eng: e.scores(cfg, c, x))
        # every engine times the full eval batch — the matmul-form indexed
        # path removed the old 2-sample truncation (no residual cap)
        r[f"infer_{name}_us"] = _timeit(fn, cache, x_eval) / n_eval * 1e6
    if "dense" in engines:
        for name in engines:
            if name != "dense":
                r[f"infer_speedup_{name}"] = (r["infer_dense_us"]
                                              / r[f"infer_{name}_us"])

    # training: dense learning alone vs the full jit-native train_step
    # (feedback + event diff + incremental cache maintenance for the paper's
    # index — O(1) *work* per boundary crossing; wall-time constant factors
    # of the functional scatter path are runtime-specific, see EXPERIMENTS.md)
    key = jax.random.key(seed)
    plain = jax.jit(
        lambda s, x, y: tm.update_batch_sequential(cfg, s, x, y, key))
    t_plain = _timeit(plain, state, x_tr, y_tr, reps=1)

    bundle = api.init_bundle(cfg, engines=("indexed",), state=state)
    step = jax.jit(lambda b, x, y: api.train_step(b, x, y, key,
                                                  max_events=512))
    t_idx = _timeit(step, bundle, x_tr, y_tr, reps=1)
    r["train_plain_us"] = t_plain / n_train * 1e6
    r["train_indexed_us"] = t_idx / n_train * 1e6
    r["train_speedup"] = t_plain / t_idx
    return r


GRID_FAMILIES = [mnist_like, fmnist_like]
CLAUSE_GRID = (256, 1024, 4096)


# ---------------------------------------------------------------------------
# Engine × backend × topology sweep (kernel backend registry, DESIGN.md §8)
# ---------------------------------------------------------------------------


def backend_topology_sweep(*, engines=("bitpack", "indexed"),
                           backends=None, n_eval=32, n_train=8,
                           seed=0) -> list[dict]:
    """Inference + train-step timings per (engine × backend × topology).

    Backends come from the kernel registry (``kernels/backend.py``):
    ``xla`` and ``pallas_interpret`` everywhere, plus compiled ``pallas``
    when this host is a TPU. Topologies: single-device always, plus — when
    the host exposes ≥ 4 devices (CI forces 4 via
    ``--xla_force_host_platform_device_count``) — a 4-way clause-sharded
    placement and a **ragged** 2×2 data×clause placement on a smaller
    clause count whose per-shard slice does not divide by the data ranks
    (``composition='composed_ragged'``, DESIGN.md §9), so the composed
    hierarchical route is timed alongside the even ones. Every row records
    its ``data_shards`` and the fired ``composition`` rule. Interpret-mode
    rows measure the *route* (they execute the kernel body in Python, so
    their magnitudes are not comparable to compiled rows — recorded for
    completeness, compared only like-for-like across PRs).
    """
    from repro.core.session import TMSession, Topology
    from repro.kernels import backend as kbackend

    if backends is None:
        backends = ("xla", "pallas_interpret")
        if jax.default_backend() == "tpu":
            backends += ("pallas",)
    cfg0 = TMConfig(n_classes=10, n_clauses=256, n_features=196)
    # clause_shards=2 → n_local=65; data_shards=2 does not divide it →
    # the previously-replicated shape that now composes raggedly
    cfg_ragged = dataclasses.replace(cfg0, n_clauses=130)
    topo_grid = [(cfg0, Topology())]
    if jax.local_device_count() >= 4:
        topo_grid.append((cfg0, Topology(clause_shards=4)))
        topo_grid.append((cfg_ragged, Topology(clause_shards=2,
                                               data_shards=2)))

    states = {
        c.n_clauses: synthetic_trained_state(
            dataclasses.replace(c, backend="xla"), 58.0, seed)
        for c, _ in topo_grid}
    rng = np.random.default_rng(seed)
    xs = jnp.asarray(rng.integers(0, 2, (n_eval, cfg0.n_features)), jnp.uint8)
    txs = jnp.asarray(rng.integers(0, 2, (n_train, cfg0.n_features)),
                      jnp.uint8)
    tys = jnp.asarray(rng.integers(0, cfg0.n_classes, n_train), jnp.int32)
    key = jax.random.key(seed)

    rows = []
    for backend in backends:
        for cfg_base, topo in topo_grid:
            cfg = dataclasses.replace(cfg_base, backend=backend)
            for engine in engines:
                # donate=False: the timing loop reuses one bundle across reps
                session = TMSession(
                    cfg, dataclasses.replace(topo, engines=(engine,),
                                             donate=False))
                bundle = session.prepare(states[cfg.n_clauses])
                fn = lambda b, x: session.scores(b, x, engine=engine)
                t_inf = _timeit(fn, bundle, xs)
                t_tr = _timeit(
                    lambda b, x, y: session.train_step(b, x, y, key),
                    bundle, txs, tys, reps=1)
                rows.append({
                    "engine": engine,
                    "backend": kbackend.resolve_backend(backend),
                    "n_clauses": cfg.n_clauses,
                    "clause_shards": topo.clause_shards,
                    "data_shards": topo.data_shards,
                    "composition": session.describe()["composition"],
                    "devices": jax.local_device_count(),
                    "infer_us": t_inf / n_eval * 1e6,
                    "train_us": t_tr / n_train * 1e6,
                })
    return rows


# ---------------------------------------------------------------------------
# Indexed vs dense speedup curve (the paper's headline claim, schema 4)
# ---------------------------------------------------------------------------


def indexed_speedup_curve(*, clause_grid=(64, 256), avg_lens=(8.0, 58.0),
                          n_features=196, n_eval=32, seed=0) -> list[dict]:
    """Indexed-vs-dense inference over (n_clauses × clause sparsity).

    The paper's Tables 1–2 trend in miniature: speedup grows with clause
    count and with sparsity (short clauses → tiny work ratio). Both engines
    time the *full* eval batch through the registry on the ``xla`` backend
    (the indexed route is the matmul-form Eq. 4 body); ``work_ratio`` is
    the hardware-independent §3 Remarks quantity recorded next to the
    measured wall-clock ratio. CI gates the sparsest high-clause cell:
    indexed must strictly beat dense there.
    """
    rows = []
    for n_c in clause_grid:
        for avg_len in avg_lens:
            cfg = TMConfig(n_classes=10, n_clauses=n_c,
                           n_features=n_features, backend="xla",
                           index_capacity=n_c)
            state = synthetic_trained_state(cfg, avg_len, seed)
            rng = np.random.default_rng(seed)
            xs = jnp.asarray(rng.integers(0, 2, (n_eval, n_features)),
                             jnp.uint8)
            row = {"n_clauses": n_c, "avg_clause_len": avg_len,
                   "features": n_features,
                   "work_ratio": work_ratio(cfg, state, xs)}
            for name in ("dense", "indexed"):
                eng = get_engine(name)
                cache = jax.jit(lambda s, e=eng: e.prepare(cfg, s))(state)
                fn = jax.jit(lambda c, x, e=eng: e.scores(cfg, c, x))
                row[f"infer_{name}_us"] = _timeit(fn, cache, xs) / n_eval * 1e6
            row["speedup"] = row["infer_dense_us"] / row["infer_indexed_us"]
            rows.append(row)
    return rows


def print_indexed_speedup(rows: list[dict]) -> None:
    """One line per indexed-speedup cell (shared with benchmarks/run.py)."""
    for r in rows:
        print(f"indexed_speedup/n{r['n_clauses']}/len{r['avg_clause_len']:g}:"
              f" dense={r['infer_dense_us']:.2f}us"
              f" indexed={r['infer_indexed_us']:.2f}us"
              f" speedup={r['speedup']:.2f}x work={r['work_ratio']:.4f}")


# ---------------------------------------------------------------------------
# Sync vs async stale-vote training sweep (DESIGN.md §11)
# ---------------------------------------------------------------------------


def train_sync_vs_async(*, ks=(0, 1, 4, 16), shard_grid=(2, 4), batch=32,
                        steps_timed=16, steps_train=48, n_eval=256,
                        seed=0) -> list[dict]:
    """Sequential train-step time + accuracy per (async_votes K × shards).

    K=0 is today's synchronous path (one vote psum per class round inside
    the batch scan + a per-step overflow psum); K>0 trains against the
    K-step-stale vote sum with the refresh all-reduce amortised into the
    timed window — so ``speedup_vs_sync`` is exactly the removed-collective
    win. Every row also trains a fresh machine on the same synthetic
    binarized-image stream and records its held-out ``accuracy`` next to
    the K=0 row's (``accuracy_delta``) — the parity the async mode must
    hold (the gate itself lives in tests/test_tm_async.py; this records
    the magnitudes). Empty on hosts with fewer devices than
    ``max(shard_grid)`` (CI forces 4).
    """
    from repro.core.session import TMSession, Topology
    from repro.core.types import init_tm

    if jax.local_device_count() < max(shard_grid):
        return []
    cfg = TMConfig(n_classes=10, n_clauses=128, n_features=196,
                   backend="xla")
    xs, ys = binarized_images(batch * steps_train + n_eval, cfg.n_features,
                              cfg.n_classes, seed=seed)
    xs, ys = jnp.asarray(xs), jnp.asarray(ys)
    x_ev, y_ev = xs[:n_eval], ys[:n_eval]
    xt, yt = xs[n_eval:], ys[n_eval:]

    rows = []
    for shards in shard_grid:
        sync_row = None
        for k in ks:
            session = TMSession(
                cfg, Topology(clause_shards=shards, async_votes=k,
                              engines=("dense",), donate=False))
            bundle = session.prepare(init_tm(cfg))
            key = jax.random.key(seed)
            for i in range(steps_train):  # accuracy + executable warmup
                key, sub = jax.random.split(key)
                b0 = i * batch
                bundle = session.train_step(
                    bundle, xt[b0:b0 + batch], yt[b0:b0 + batch], sub)
            bundle = session.refresh_votes(bundle)
            acc = float(jnp.mean(
                (session.predict(bundle, x_ev, engine="dense")
                 == y_ev).astype(jnp.float32)))
            jax.block_until_ready(bundle.state.ta_state)
            t0 = time.perf_counter()
            for i in range(steps_timed):  # amortises the K-step refreshes
                key, sub = jax.random.split(key)
                b0 = (i % steps_train) * batch
                bundle = session.train_step(
                    bundle, xt[b0:b0 + batch], yt[b0:b0 + batch], sub)
            jax.block_until_ready(bundle.state.ta_state)
            step_us = (time.perf_counter() - t0) / steps_timed * 1e6
            row = {"k": k, "clause_shards": shards,
                   "data_shards": 1,
                   "composition": session.describe()["composition"],
                   "devices": jax.local_device_count(),
                   "batch": batch, "step_us": step_us, "accuracy": acc}
            if k == 0:
                sync_row = row
            row["accuracy_sync"] = sync_row["accuracy"]
            row["accuracy_delta"] = acc - sync_row["accuracy"]
            row["speedup_vs_sync"] = sync_row["step_us"] / step_us
            rows.append(row)
    return rows


def print_sync_vs_async(rows: list[dict]) -> None:
    """One line per sync-vs-async row (shared with benchmarks/run.py)."""
    for r in rows:
        print(f"sync_vs_async/c{r['clause_shards']}/K={r['k']}"
              f"[{r['composition']}]: step={r['step_us']:.0f}us "
              f"speedup={r['speedup_vs_sync']:.2f}x "
              f"acc={r['accuracy']:.3f} (Δ{r['accuracy_delta']:+.3f})")


def run(fast: bool = True, engines=DEFAULT_ENGINES):
    rows = []
    clause_grid = CLAUSE_GRID[:2] if fast else CLAUSE_GRID
    for fam in GRID_FAMILIES:
        for bits in ((1, 2) if fast else (1, 2, 3, 4)):
            for n_c in clause_grid:
                rows.append(bench_cell(fam(bits), n_c, engines=engines))
    for o in ((5000,) if fast else (5000, 10000, 20000)):
        for n_c in clause_grid:
            rows.append(bench_cell(imdb_like(o), n_c, engines=engines))
    return rows


def print_sweep(sweep: list[dict], prefix: str = "sweep") -> None:
    """One line per backend-sweep row (shared by main and benchmarks/run.py)."""
    for r in sweep:
        print(f"{prefix}/{r['engine']}/{r['backend']}"
              f"/c{r['clause_shards']}xd{r['data_shards']}"
              f"[{r['composition']}]: "
              f"infer={r['infer_us']:.2f}us train={r['train_us']:.2f}us")


def write_json(rows, path: str = "BENCH_tm.json",
               backend_sweep=None, train_sync_vs_async=None,
               indexed_speedup=None) -> None:
    """Machine-readable perf record, one file per run (tracked across PRs)."""
    payload = {
        "bench": "tm_speedup",
        "schema": 4,
        "backend": jax.default_backend(),
        "host": platform.machine(),
        "devices": jax.local_device_count(),
        "units": {"infer_*_us": "us/sample", "train_*_us": "us/sample",
                  "step_us": "us/step",
                  "work_ratio": "indexed/dense literal inspections"},
        "rows": rows,
        "backend_sweep": backend_sweep or [],
        "train_sync_vs_async": train_sync_vs_async or [],
        "indexed_speedup": indexed_speedup or [],
    }
    with open(path, "w") as f:
        json.dump(payload, f, indent=1, sort_keys=True)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--engines", default=",".join(DEFAULT_ENGINES))
    ap.add_argument("--out", default="BENCH_tm.json",
                    help="JSON output path ('' to skip)")
    ap.add_argument("--sweep-only", action="store_true",
                    help="run only the engine×backend×topology sweep "
                         "(the CI gate on a forced multi-device host)")
    args = ap.parse_args()
    engines = tuple(args.engines.split(","))

    if args.sweep_only:
        sweep = backend_topology_sweep()
        print_sweep(sweep)
        curve = indexed_speedup_curve()
        print_indexed_speedup(curve)
        sva = train_sync_vs_async()
        print_sync_vs_async(sva)
        if args.out:
            write_json([], args.out, backend_sweep=sweep,
                       train_sync_vs_async=sva, indexed_speedup=curve)
        return

    rows = run(fast=not args.full, engines=engines)
    cols = ["family", "features", "clauses", "work_ratio"]
    cols += [f"infer_{e}_us" for e in engines]
    if "dense" in engines:  # speedups are only defined against the baseline
        cols += [f"infer_speedup_{e}" for e in engines if e != "dense"]
    cols += ["train_plain_us", "train_indexed_us", "train_speedup"]
    print(",".join(cols))
    for r in rows:
        print(",".join(
            f"{r[c]:.4g}" if isinstance(r[c], float) else str(r[c])
            for c in cols))
    sweep = backend_topology_sweep()
    print_sweep(sweep)
    curve = indexed_speedup_curve()
    print_indexed_speedup(curve)
    sva = train_sync_vs_async()
    print_sync_vs_async(sva)
    if args.out:
        write_json(rows, args.out, backend_sweep=sweep,
                   train_sync_vs_async=sva, indexed_speedup=curve)


if __name__ == "__main__":
    main()
