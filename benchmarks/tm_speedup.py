"""Paper Tables 1–3 analogue: indexed vs exhaustive TM throughput.

Grid: (dataset-family × features × clauses), measuring
  * inference us/sample for engines dense | bitpack | compact | indexed
  * training  us/sample for dense-learning with / without index maintenance
  * the §3 'Remarks' WORK RATIO (indexed literal-inspections / dense),
    which is hardware-independent — the paper's 0.02 (MNIST) / 0.006 (IMDb)
    claims are validated here exactly.

Container scaling: sample counts and the clause grid are scaled down for
the 1-core CPU (the paper used full datasets on a desktop CPU); trends —
speedup grows with clause count, IMDb training slows down under index
maintenance — are the reproduction target, magnitudes are host-specific.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.tm import fmnist_like, imdb_like, mnist_like
from repro.core import indexing, tm
from repro.core.driver import TMDriver
from repro.core.types import TMConfig, TMState, include_mask
from repro.data.synthetic import binarized_images, bow_documents


def _timeit(fn, *args, reps=3, warmup=1):
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        best = min(best, time.perf_counter() - t0)
    return best


def synthetic_trained_state(cfg: TMConfig, avg_clause_len: float, seed=0):
    """TM state with paper-matched clause sparsity (include prob =
    avg_len / 2o), standing in for a trained machine's sparsity profile."""
    rng = np.random.default_rng(seed)
    p = avg_clause_len / cfg.n_literals
    inc = rng.uniform(size=(cfg.n_classes, cfg.n_clauses,
                            cfg.n_literals)) < p
    ta = np.where(inc, cfg.n_states + 1, cfg.n_states).astype(np.int16)
    return TMState(ta_state=jnp.asarray(ta))


def work_ratio(cfg: TMConfig, state: TMState, xs) -> float:
    """Paper §3 Remarks: (Σ_{k false} |L_k|) / (n·2o) per class-eval."""
    idx = indexing.build_index(cfg, state, cfg.n_clauses)
    w = np.asarray(indexing.indexed_work(idx, xs)).mean()
    return float(w) / indexing.dense_work(cfg)


def bench_cell(exp, n_clauses: int, *, n_eval=32, n_train=16, seed=0):
    cfg = jax.tree_util.tree_map(lambda x: x, exp.tm)  # copy
    import dataclasses
    cfg = dataclasses.replace(exp.tm, n_clauses=n_clauses)
    if exp.dataset == "image":
        xs, ys = binarized_images(n_eval + n_train, cfg.n_features,
                                  cfg.n_classes, seed=seed)
    else:
        xs, ys = bow_documents(n_eval + n_train, cfg.n_features,
                               cfg.n_classes, seed=seed)
    xs = jnp.asarray(xs)
    ys = jnp.asarray(ys)
    x_eval, y_eval = xs[:n_eval], ys[:n_eval]
    x_tr, y_tr = xs[n_eval:], ys[n_eval:]

    state = synthetic_trained_state(cfg, exp.avg_clause_len, seed)
    # realistic list capacity: 4× the expected list length (cf. MoE capacity
    # factor); worst-case n_clauses capacity makes the scatter path do
    # n/len× more masked work (§Perf hillclimb C)
    cap = min(cfg.n_clauses,
              max(16, int(4 * n_clauses * exp.avg_clause_len
                          / cfg.n_literals)))
    drv = TMDriver(cfg=cfg, state=state,
                   index=indexing.build_index(cfg, state, cap))

    r: dict = {"family": exp.name, "features": cfg.n_features,
               "clauses": n_clauses}
    r["work_ratio"] = work_ratio(cfg, state, x_eval)

    # inference engines — state/index passed as jit ARGS (a closure
    # constant triggers multi-second XLA constant folding of the packed
    # tables and pollutes logs)
    lmax = int(np.asarray(include_mask(cfg, state).sum(-1)).max())
    comp = indexing.compact(cfg, state, max(lmax, 1))
    fns = {
        "dense": (jax.jit(lambda s, x: tm.scores(cfg, s, x)), state),
        "bitpack": (jax.jit(lambda s, x: tm.bitpacked_scores(cfg, s, x)),
                    state),
        "indexed": (jax.jit(
            lambda i, x: indexing.indexed_scores(cfg, i, x)), drv.index),
        "compact": (jax.jit(
            lambda c, x: indexing.compact_scores(cfg, c, x)), comp),
    }
    for name, (fn, op) in fns.items():
        xs_t = x_eval if name != "indexed" else x_eval[:2]
        r[f"infer_{name}_us"] = _timeit(fn, op, xs_t) / xs_t.shape[0] * 1e6
    r["infer_speedup_indexed"] = (r["infer_dense_us"]
                                  / r["infer_indexed_us"])
    r["infer_speedup_compact"] = (r["infer_dense_us"]
                                  / r["infer_compact_us"])

    # training: dense learning, with vs without incremental index
    # maintenance (index prebuilt; the timed delta is the event replay —
    # O(1) *work* per boundary crossing; wall-time constant factors of the
    # functional scatter path are runtime-specific, see EXPERIMENTS.md)
    key = jax.random.key(seed)
    plain = jax.jit(
        lambda s, x, y: tm.update_batch_sequential(cfg, s, x, y, key))
    t_plain = _timeit(plain, state, x_tr, y_tr, reps=1)

    from repro.core.types import include_mask as _inc
    max_ev = 512

    @jax.jit
    def with_index(s, idx, x, y):
        old = _inc(cfg, TMState(ta_state=s))
        new_s = tm.update_batch_sequential(cfg, TMState(ta_state=s), x, y,
                                           key)
        events = indexing.events_from_transition(
            old, _inc(cfg, new_s), max_ev)
        return new_s.ta_state, indexing.apply_events(idx, events)
    t_idx = _timeit(with_index, state.ta_state, drv.index, x_tr, y_tr,
                    reps=1)
    r["train_plain_us"] = t_plain / n_train * 1e6
    r["train_indexed_us"] = t_idx / n_train * 1e6
    r["train_speedup"] = t_plain / t_idx
    return r


GRID_FAMILIES = [mnist_like, fmnist_like]
CLAUSE_GRID = (256, 1024, 4096)


def run(fast: bool = True):
    rows = []
    clause_grid = CLAUSE_GRID[:2] if fast else CLAUSE_GRID
    for fam in GRID_FAMILIES:
        for bits in ((1, 2) if fast else (1, 2, 3, 4)):
            for n_c in clause_grid:
                rows.append(bench_cell(fam(bits), n_c))
    for o in ((5000,) if fast else (5000, 10000, 20000)):
        for n_c in clause_grid:
            rows.append(bench_cell(imdb_like(o), n_c))
    return rows


def main():
    rows = run(fast=True)
    cols = ["family", "features", "clauses", "work_ratio",
            "infer_dense_us", "infer_indexed_us", "infer_compact_us",
            "infer_bitpack_us", "infer_speedup_indexed",
            "infer_speedup_compact", "train_plain_us", "train_indexed_us",
            "train_speedup"]
    print(",".join(cols))
    for r in rows:
        print(",".join(
            f"{r[c]:.4g}" if isinstance(r[c], float) else str(r[c])
            for c in cols))


if __name__ == "__main__":
    main()
