"""LM micro-bench: wall-time of reduced-config train/prefill/decode steps.

Complements the dry-run (which measures the compiled artifact, not wall
time): on this CPU host we time the REDUCED configs end to end, proving
the full step path executes, and report us/token per family.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCHS, get_config, reduce_config
from repro.configs.base import ShapeSpec
from repro.models.model import build, input_specs
from repro.optim import adamw, compression
from repro.sharding import Policy
from repro.steps import make_train_step

SHAPE = ShapeSpec("bench", "train", 64, 4)


def bench_arch(arch: str) -> dict:
    cfg = reduce_config(get_config(arch))
    step = make_train_step(cfg, SHAPE, None, microbatches=2)
    model = build(cfg)
    params = model.init(jax.random.key(0)) if cfg.family != "encdec" else \
        model.init(jax.random.key(0), 128)
    state = {"params": params, "opt": adamw.init(params),
             "ef": compression.init_error_feedback(params)}
    rng = np.random.default_rng(0)
    batch = {}
    for k, v in input_specs(cfg, SHAPE, concrete=True).items():
        if v.dtype == jnp.int32:
            batch[k] = jnp.asarray(
                rng.integers(0, cfg.vocab, v.shape), jnp.int32)
        else:
            batch[k] = jnp.asarray(rng.normal(size=v.shape) * 0.02, v.dtype)
    fn = jax.jit(step.fn)
    state, m = fn(state, batch)          # compile
    jax.block_until_ready(m["loss"])
    t0 = time.perf_counter()
    reps = 3
    for _ in range(reps):
        state, m = fn(state, batch)
    jax.block_until_ready(m["loss"])
    dt = (time.perf_counter() - t0) / reps
    return {"arch": arch, "family": cfg.family,
            "us_per_token": dt / SHAPE.tokens * 1e6,
            "loss_finite": bool(jnp.isfinite(m["loss"]))}


def main():
    print("arch,family,us_per_token,loss_finite")
    for arch in ARCHS:
        r = bench_arch(arch)
        print(f"{r['arch']},{r['family']},{r['us_per_token']:.1f},"
              f"{r['loss_finite']}")


if __name__ == "__main__":
    main()
