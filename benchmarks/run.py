"""Benchmark harness entrypoint: one section per paper table + LM bench.

    PYTHONPATH=src python -m benchmarks.run [--full | --smoke]

Sections:
  [tm_speedup]  paper Tables 1–3 analogue — per-engine TM throughput via the
                engine registry + the §3 work-ratio validation (0.02 / 0.006);
                also written to BENCH_tm.json for cross-PR tracking
  [work_ratio]  hardware-independent reproduction of the paper's Remarks
  [serving]     closed-loop tail latency + open-loop sync-vs-async knee
                (serving runtime, DESIGN.md §10) via repro.launch.tm_serve
  [lm_step]     reduced-config LM step wall-times (all 10 archs)

``--smoke`` runs a single scaled-down TM cell (no JSON, no LM zoo) — the CI
sanity path used by scripts/ci.sh.

Roofline numbers (dry-run-derived, not wall-time) live in results/ and
EXPERIMENTS.md; regenerate with launch/roofline_sweep.py.
"""
from __future__ import annotations

import argparse


def _print_tm_row(r: dict) -> None:
    base = f"tm/{r['family']}/o{r['features']}/c{r['clauses']}"
    for eng in r["engines"]:
        speed = r.get(f"infer_speedup_{eng}")
        suffix = f"speedup={speed:.2f}" if speed is not None else ""
        print(f"{base}/infer_{eng},{r[f'infer_{eng}_us']:.2f},{suffix}")
    print(f"{base}/train_plain,{r['train_plain_us']:.2f},")
    print(f"{base}/train_indexed,{r['train_indexed_us']:.2f},"
          f"speedup={r['train_speedup']:.2f}")
    print(f"{base}/work_ratio,,{r['work_ratio']:.5f}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="full grids (slow on 1 CPU core)")
    ap.add_argument("--smoke", action="store_true",
                    help="one tiny TM cell only (CI sanity check)")
    ap.add_argument("--skip-lm", action="store_true")
    args = ap.parse_args()

    print("name,us_per_call,derived")

    from benchmarks import tm_speedup
    from repro.configs.tm import imdb_like, mnist_like

    if args.smoke:
        row = tm_speedup.bench_cell(mnist_like(1), 64, n_eval=8, n_train=4)
        _print_tm_row(row)
        return

    # --- paper tables: TM speedup grid -----------------------------------
    rows = tm_speedup.run(fast=not args.full)
    for r in rows:
        _print_tm_row(r)

    # --- engine × backend × topology sweep (kernel backend registry) ------
    sweep = tm_speedup.backend_topology_sweep()
    tm_speedup.print_sweep(sweep, prefix="tm/sweep")

    # --- indexed vs dense speedup curve (matmul-form Eq. 4, schema 4) ------
    curve = tm_speedup.indexed_speedup_curve()
    tm_speedup.print_indexed_speedup(curve)
    tm_speedup.write_json(rows, backend_sweep=sweep, indexed_speedup=curve)

    # --- paper §3 Remarks: analytic work ratios at paper scale ------------
    from repro.core.indexing import dense_work
    for exp, n_c in ((mnist_like(2, 20000), 20000),
                     (imdb_like(20000, 20000), 20000)):
        import dataclasses
        cfg = dataclasses.replace(exp.tm, n_clauses=n_c)
        # E[work]/dense = (#false literals × avg list len)/(n·2o)
        #              = o × (n·len/2o) / (n·2o) = len/(4o) × ... exact:
        ratio = (cfg.n_features * exp.avg_clause_len * cfg.n_clauses
                 / cfg.n_literals) / dense_work(cfg) * cfg.n_classes
        print(f"tm/paper_scale/{exp.name}/analytic_work_ratio,,"
              f"{ratio:.5f}")

    # --- TM serving tail latency (batched inference path) -----------------
    from repro.core.types import TMConfig
    from repro.launch import tm_serve
    serve_rec = tm_serve.run(
        TMConfig(n_classes=10, n_clauses=256, n_features=196),
        engines=("indexed", "bitpack_xla", "compact"),
        n_requests=256 if not args.full else 2048, rps=1000.0)
    for eng, r in serve_rec["engines"].items():
        lm_ = r["latency_ms"]
        print(f"tm/serve/{eng}/p95,{lm_['p95'] * 1e3:.2f},"
              f"p99_ms={lm_['p99']} thru_rps={r['throughput_rps']}")

    # --- TM serving: open-loop sync-vs-async knee (DESIGN.md §10) ---------
    sus = tm_serve.run_sustained(
        TMConfig(n_classes=10, n_clauses=256, n_features=196),
        engines=("indexed", "bitpack_xla") if args.full
        else ("bitpack_xla",),
        max_batch=32, step_duration_s=1.0 if args.full else 0.5)
    for eng, r in sus["engines"].items():
        knee, base = r["knee"], r["sync_baseline"]["achieved_rps"]
        print(f"tm/serve_async/{eng}/knee_rps,,{knee['achieved_rps']:.1f} "
              f"sync_rps={base:.1f} speedup={r['speedup_at_knee']} "
              f"exceeds={r['knee_exceeds_sync']} "
              f"hot_loop_compiles={r['aot']['hot_loop_compiles']}")

    # --- LM zoo step wall-times -------------------------------------------
    if not args.skip_lm:
        from benchmarks import lm_step
        for arch in __import__("repro.configs", fromlist=["ARCHS"]).ARCHS:
            r = lm_step.bench_arch(arch)
            print(f"lm/{r['arch']}/train_step,{r['us_per_token']:.2f},"
                  f"family={r['family']} finite={r['loss_finite']}")


if __name__ == "__main__":
    main()
